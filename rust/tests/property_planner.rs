//! Property-based tests over the planner + recovery substrate (hand-rolled
//! generator loop — no proptest in the vendor set; every case prints its
//! seed so failures are reproducible).
//!
//! Invariants:
//! * any feasible plan is structurally valid and uses every GPU once
//! * the exact solver never loses to the LPT heuristic (any kind count)
//! * the device-subset solver never loses to the all-devices solver, and
//!   its solutions' used+benched counts always reconcile
//! * layer partitions cover the model and respect memory caps
//! * on *randomized catalogs of 2–6 kinds*: every group meets the model
//!   memory floor, no TP entity crosses a node, and the Eq-3 objective is
//!   monotone when a device of the strongest kind is added
//! * TP reshard round-trips for every (tp_old, tp_new) pair
//! * spot traces never leave capacity bounds; events replay exactly

use autohet::checkpoint::shard;
use autohet::cluster::{ClusterSpec, GpuCatalog, GpuSpec, KindId, KindVec, SpotTrace, TraceConfig};
use autohet::modelcfg::ModelCfg;
use autohet::planner::partition::{partition_layers, StageRes};
use autohet::planner::solver::{lpt_heuristic, solve, solve_subsets, EntitySpec, GroupingProblem};
use autohet::planner::{auto_plan, PlanOptions};
use autohet::profile::ProfileDb;
use autohet::runtime::HostTensor;
use autohet::util::rng::Rng;

const CASES: usize = 40;

fn random_cluster(rng: &mut Rng) -> ClusterSpec {
    let kinds = [KindId::A100, KindId::H800, KindId::H20];
    let n_nodes = 1 + rng.below(4);
    let counts: Vec<(usize, KindId)> = (0..n_nodes)
        .map(|_| (1 + rng.below(8), kinds[rng.below(3)]))
        .collect();
    ClusterSpec::from_counts(&counts)
}

/// Random catalog of 2–6 kinds with bounded power/memory ratios.
fn random_catalog(rng: &mut Rng) -> GpuCatalog {
    let k = 2 + rng.below(5);
    let mut cat = GpuCatalog::empty();
    for i in 0..k {
        let power = 0.5 + rng.f64() * 3.5; // g_i ∈ [0.5, 4.0)
        cat.add(GpuSpec {
            name: format!("G{i}"),
            relative_power: power,
            flops_tf: 140.0 * power,
            mem_gib: 48.0 + rng.f64() * 144.0, // [48, 192) GiB
            nvlink_gbs: 400.0 + rng.f64() * 500.0,
            hbm_gbs: 1600.0,
            // deterministic so the rng stream (and thus every seeded
            // case below) stays identical to the pre-economics suite
            price_per_hour: 1.2 * power,
            rdma_nics: 1 + i % 8,
        })
        .unwrap();
    }
    cat
}

#[test]
fn any_feasible_plan_is_valid_and_exact_cover() {
    let model = ModelCfg::bert_large();
    let profile = ProfileDb::build(&model, &GpuCatalog::builtin(), &[1, 2, 4, 8], 3);
    let mut rng = Rng::new(0xBEEF);
    let mut planned = 0;
    for case in 0..CASES {
        let cluster = random_cluster(&mut rng);
        if let Ok(plan) = auto_plan(&cluster, &profile, &PlanOptions::default()) {
            plan.validate(model.n_layers)
                .unwrap_or_else(|e| panic!("case {case} ({cluster:?}): {e}"));
            assert_eq!(
                plan.gpu_count(),
                cluster.total_gpus(),
                "case {case}: not an exact GPU cover"
            );
            planned += 1;
        }
    }
    assert!(planned > CASES / 2, "planner failed too often: {planned}/{CASES}");
}

#[test]
fn randomized_catalog_plans_respect_memory_and_locality() {
    // The catalog invariants on arbitrary 2–6-kind fleets: every DP
    // group's aggregate memory covers the model floor (Eq 3b), and no TP
    // entity (stage) spans two nodes (§III-C).
    let model = ModelCfg::bert_large();
    let min_mem_gib = model.min_mem_bytes() / f64::powi(2.0, 30);
    let mut rng = Rng::new(0xD1CE);
    let mut planned = 0;
    for case in 0..CASES {
        let cat = random_catalog(&mut rng);
        let n_nodes = 1 + rng.below(4);
        let counts: Vec<(usize, KindId)> = (0..n_nodes)
            .map(|_| (1 + rng.below(6), KindId(rng.below(cat.len()))))
            .collect();
        let cluster = ClusterSpec::from_counts_in(&cat, &counts);
        let profile = ProfileDb::build(&model, &cat, &[1, 2, 4, 8], case as u64);
        let Ok(plan) = auto_plan(&cluster, &profile, &PlanOptions::default()) else {
            continue;
        };
        planned += 1;
        plan.validate(model.n_layers)
            .unwrap_or_else(|e| panic!("case {case} ({cluster:?}): {e}"));
        assert_eq!(plan.gpu_count(), cluster.total_gpus(), "case {case}");
        for (gi, g) in plan.groups.iter().enumerate() {
            let group_mem: f64 = g
                .stages
                .iter()
                .map(|s| s.gpus.len() as f64 * cat.get(s.kind).mem_gib)
                .sum();
            assert!(
                group_mem + 1e-9 >= min_mem_gib,
                "case {case} group {gi}: {group_mem:.0} GiB < floor {min_mem_gib:.0}"
            );
            for (si, s) in g.stages.iter().enumerate() {
                assert!(
                    s.gpus.iter().all(|r| r.node == s.gpus[0].node),
                    "case {case} group {gi} stage {si}: TP entity crosses nodes"
                );
                let node = cluster.node(s.gpus[0].node).unwrap();
                assert_eq!(s.kind, node.kind, "case {case}: stage kind != node kind");
            }
        }
    }
    assert!(planned > CASES / 2, "planner failed too often: {planned}/{CASES}");
}

#[test]
fn objective_monotone_when_adding_strongest_device() {
    // Adding one entity of the strongest kind can never lower the Eq-3
    // objective: the incumbent J can absorb it into its weakest group.
    // (Generous microbatch counts keep the bubble delta second-order;
    // adding a *weak* straggler can legitimately hurt under exact
    // coverage, so only the strongest kind carries this guarantee.)
    let mut rng = Rng::new(0x5EED5);
    for case in 0..CASES {
        let cat = random_catalog(&mut rng);
        let kdim = cat.len();
        let mut counts = KindVec::new(kdim, 0usize);
        for i in 0..kdim {
            counts[i] = rng.below(3);
        }
        if counts.total() == 0 || counts.total() > 9 {
            continue; // keep the exact solver in play for every J
        }
        let entity: KindVec<EntitySpec> = KindVec::from(
            cat.specs()
                .iter()
                .map(|s| EntitySpec { power: s.relative_power, mem_gib: s.mem_gib })
                .collect::<Vec<_>>(),
        );
        let strongest = (0..kdim)
            .max_by(|&a, &b| entity[a].power.partial_cmp(&entity[b].power).unwrap())
            .unwrap();
        let problem = GroupingProblem {
            counts: counts.clone(),
            entity: entity.clone(),
            min_mem_gib: 40.0, // below every entity's memory: singletons ok
            microbatches_total: 64,
            deadline: None,
        };
        let before = solve(&problem).map(|s| s.objective);
        let mut grown = counts.clone();
        grown[strongest] += 1;
        let after = solve(&GroupingProblem { counts: grown, ..problem })
            .map(|s| s.objective)
            .unwrap_or_else(|| panic!("case {case}: growing made instance infeasible"));
        if let Some(before) = before {
            assert!(
                after >= before - 1e-9,
                "case {case}: objective fell {before} -> {after} ({counts:?} +G{strongest})"
            );
        }
    }
}

#[test]
fn exact_solver_never_below_lpt() {
    // Random kind counts (2–6) and per-kind entity specs: the exact B&B
    // must match or beat the LPT greedy at every feasible J.
    let mut rng = Rng::new(0xCAFE);
    for case in 0..CASES {
        let cat = random_catalog(&mut rng);
        let kdim = cat.len();
        let mut counts = KindVec::new(kdim, 0usize);
        for i in 0..kdim {
            counts[i] = rng.below(5);
        }
        if counts.total() == 0 {
            continue;
        }
        let entity: KindVec<EntitySpec> = KindVec::from(
            cat.specs()
                .iter()
                .map(|s| EntitySpec { power: s.relative_power, mem_gib: s.mem_gib })
                .collect::<Vec<_>>(),
        );
        let min_mem = 40.0 + rng.f64() * 120.0;
        let total_mb = 8 + rng.below(56);
        let p = GroupingProblem {
            counts: counts.clone(),
            entity: entity.clone(),
            min_mem_gib: min_mem,
            microbatches_total: total_mb,
            deadline: None,
        };
        let exact = solve(&p);
        // compare against LPT at every J
        for j in 1..=counts.total() {
            let k = (total_mb / j).max(1);
            if let Some((_, lpt_min)) = lpt_heuristic(&counts, &entity, min_mem, j, k) {
                let lpt_obj = j as f64 * lpt_min;
                let exact_obj = exact.as_ref().map(|s| s.objective).unwrap_or(f64::NEG_INFINITY);
                assert!(
                    exact_obj >= lpt_obj - 1e-9,
                    "case {case}: exact {exact_obj} < lpt {lpt_obj} (j={j}, counts {counts:?}, mem {min_mem:.0})"
                );
            }
        }
    }
}

#[test]
fn subset_solver_never_below_all_devices() {
    // Relaxing exact coverage can only help: the subset enumeration
    // always contains the zero-bench (all-devices) solution, so its best
    // objective dominates `solve`'s. Used + benched must reconcile with
    // the instance counts for every returned subset.
    let mut rng = Rng::new(0x5B5E7);
    for case in 0..CASES {
        let cat = random_catalog(&mut rng);
        let kdim = cat.len();
        let mut counts = KindVec::new(kdim, 0usize);
        for i in 0..kdim {
            counts[i] = rng.below(3);
        }
        if counts.total() == 0 || counts.total() > 8 {
            continue; // keep the exact solver in play for every subset
        }
        let entity: KindVec<EntitySpec> = KindVec::from(
            cat.specs()
                .iter()
                .map(|s| EntitySpec { power: s.relative_power, mem_gib: s.mem_gib })
                .collect::<Vec<_>>(),
        );
        let p = GroupingProblem {
            counts: counts.clone(),
            entity,
            min_mem_gib: 40.0 + rng.f64() * 80.0,
            microbatches_total: 8 + rng.below(56),
            deadline: None,
        };
        let all = solve(&p);
        let subs = solve_subsets(&p, None);
        for s in &subs {
            assert!(s.benched.fits_within(&counts), "case {case}");
            let mut used = KindVec::new(kdim, 0usize);
            for g in &s.solution.groups {
                for i in 0..kdim {
                    used[i] += g[i];
                }
            }
            for i in 0..kdim {
                assert_eq!(
                    used[i] + s.benched[i],
                    counts[i],
                    "case {case}: kind {i} used+benched != available"
                );
            }
        }
        let Some(all) = all else {
            continue; // all-devices infeasible; nothing to dominate
        };
        let best = subs
            .first()
            .unwrap_or_else(|| panic!("case {case}: all-devices feasible but no subsets"));
        assert!(
            best.solution.objective >= all.objective - 1e-9,
            "case {case}: subset {} < all-devices {} ({counts:?})",
            best.solution.objective,
            all.objective
        );
        // the zero-bench solution itself must be in the list, unpruned
        assert!(
            subs.iter().any(|s| s.benched.total() == 0
                && (s.solution.objective - all.objective).abs() < 1e-12),
            "case {case}: all-devices solution missing from subset list"
        );
    }
}

#[test]
fn partitions_cover_and_respect_memory() {
    let model = ModelCfg::gpt3_6p7b();
    let profile = ProfileDb::build(&model, &GpuCatalog::builtin(), &[1, 2, 4, 8], 7);
    let kinds = [KindId::A100, KindId::H800, KindId::H20];
    let mut rng = Rng::new(0xF00D);
    for case in 0..CASES {
        let p_stages = 2 + rng.below(6);
        let tp = [1usize, 2, 4, 8][rng.below(4)];
        let stages: Vec<StageRes> = (0..p_stages)
            .map(|_| StageRes { kind: kinds[rng.below(3)], tp })
            .collect();
        if let Some(layers) = partition_layers(&stages, &profile) {
            assert_eq!(
                layers.iter().sum::<usize>(),
                model.n_layers,
                "case {case}: cover"
            );
            assert!(layers.iter().all(|&l| l >= 1), "case {case}: empty stage");
            for (i, (&l, s)) in layers.iter().zip(&stages).enumerate() {
                let cap = profile.catalog.get(s.kind).mem_gib * tp as f64 * f64::powi(2.0, 30) * 0.94;
                let used = profile.mem_bytes(l, i, p_stages, tp, i == 0 || i == p_stages - 1);
                assert!(used <= cap, "case {case} stage {i}: {used:.2e} > {cap:.2e}");
            }
        }
    }
}

#[test]
fn tp_reshard_roundtrips_all_dims() {
    let mut rng = Rng::new(0xAB);
    for name in ["wqkv", "wo", "w1", "b1", "w2", "ln1_g"] {
        for _ in 0..10 {
            let d = 8 * (1 + rng.below(3));
            let full = match name {
                "wqkv" => rand_t(&mut rng, &[d, 3 * d]),
                "wo" => rand_t(&mut rng, &[d, d]),
                "w1" => rand_t(&mut rng, &[d, 4 * d]),
                "b1" => rand_t(&mut rng, &[4 * d]),
                "w2" => rand_t(&mut rng, &[4 * d, d]),
                _ => rand_t(&mut rng, &[d]),
            };
            for tp_old in [1usize, 2, 4] {
                for tp_new in [1usize, 2, 4] {
                    let olds: Vec<HostTensor> = (0..tp_old)
                        .map(|s| shard::split_for_tp(name, &full, tp_old, s).unwrap())
                        .collect();
                    let refs: Vec<&HostTensor> = olds.iter().collect();
                    // reshard to tp_new, then reassemble and compare
                    let news: Vec<HostTensor> = (0..tp_new)
                        .map(|s| shard::reshard(name, &refs, tp_new, s).unwrap())
                        .collect();
                    let nrefs: Vec<&HostTensor> = news.iter().collect();
                    let back = shard::concat_from_shards(name, &nrefs).unwrap();
                    assert_eq!(back, full, "{name} tp {tp_old}->{tp_new}");
                }
            }
        }
    }
}

fn rand_t(rng: &mut Rng, shape: &[usize]) -> HostTensor {
    let mut v = vec![0.0f32; shape.iter().product()];
    rng.fill_normal_f32(&mut v, 1.0);
    HostTensor::from_f32(shape, v)
}

#[test]
fn spot_traces_bounded_and_replayable() {
    for seed in 0..20u64 {
        let t = SpotTrace::generate(TraceConfig::default(), seed);
        for row in &t.avail {
            for (ki, &(_, cap)) in t.cfg.capacity.iter().enumerate() {
                assert!(row[ki] <= cap, "seed {seed}");
            }
        }
        // replay events from the first row and land on the last row
        let mut level: Vec<i64> = t.avail[0].iter().map(|&x| x as i64).collect();
        for ev in t.events() {
            let ki = t.kinds.iter().position(|&k| k == ev.kind).unwrap();
            level[ki] += ev.delta;
            assert!(level[ki] >= 0, "seed {seed}: negative availability");
        }
        let last: Vec<i64> = t.avail.last().unwrap().iter().map(|&x| x as i64).collect();
        assert_eq!(level, last, "seed {seed}");
    }
}
