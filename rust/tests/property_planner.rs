//! Property-based tests over the planner + recovery substrate (hand-rolled
//! generator loop — no proptest in the vendor set; every case prints its
//! seed so failures are reproducible).
//!
//! Invariants:
//! * any feasible plan is structurally valid and uses every GPU once
//! * the exact solver never loses to the LPT heuristic
//! * layer partitions cover the model and respect memory caps
//! * TP reshard round-trips for every (tp_old, tp_new) pair
//! * spot traces never leave capacity bounds; events replay exactly

use autohet::checkpoint::shard;
use autohet::cluster::{ClusterSpec, GpuKind, SpotTrace, TraceConfig};
use autohet::modelcfg::ModelCfg;
use autohet::planner::partition::{partition_layers, StageRes};
use autohet::planner::solver::{lpt_heuristic, solve, EntitySpec, GroupingProblem};
use autohet::planner::{auto_plan, PlanOptions};
use autohet::profile::ProfileDb;
use autohet::runtime::HostTensor;
use autohet::util::rng::Rng;

const CASES: usize = 40;

fn random_cluster(rng: &mut Rng) -> ClusterSpec {
    let kinds = [GpuKind::A100, GpuKind::H800, GpuKind::H20];
    let n_nodes = 1 + rng.below(4);
    let counts: Vec<(usize, GpuKind)> = (0..n_nodes)
        .map(|_| (1 + rng.below(8), kinds[rng.below(3)]))
        .collect();
    ClusterSpec::from_counts(&counts)
}

#[test]
fn any_feasible_plan_is_valid_and_exact_cover() {
    let model = ModelCfg::bert_large();
    let profile = ProfileDb::build(
        &model,
        &[GpuKind::A100, GpuKind::H800, GpuKind::H20],
        &[1, 2, 4, 8],
        3,
    );
    let mut rng = Rng::new(0xBEEF);
    let mut planned = 0;
    for case in 0..CASES {
        let cluster = random_cluster(&mut rng);
        if let Ok(plan) = auto_plan(&cluster, &profile, &PlanOptions::default()) {
            plan.validate(model.n_layers)
                .unwrap_or_else(|e| panic!("case {case} ({cluster:?}): {e}"));
            assert_eq!(
                plan.gpu_count(),
                cluster.total_gpus(),
                "case {case}: not an exact GPU cover"
            );
            planned += 1;
        }
    }
    assert!(planned > CASES / 2, "planner failed too often: {planned}/{CASES}");
}

#[test]
fn exact_solver_never_below_lpt() {
    let mut rng = Rng::new(0xCAFE);
    for case in 0..CASES {
        let counts = [rng.below(7), rng.below(5), rng.below(5)];
        if counts.iter().sum::<usize>() == 0 {
            continue;
        }
        let entity = [
            EntitySpec { power: 1.0, mem_gib: 80.0 },
            EntitySpec { power: 2.0, mem_gib: 80.0 },
            EntitySpec { power: 0.5, mem_gib: 100.0 },
        ];
        let min_mem = 40.0 + rng.f64() * 120.0;
        let total_mb = 8 + rng.below(56);
        let p = GroupingProblem {
            counts,
            entity,
            min_mem_gib: min_mem,
            microbatches_total: total_mb,
            deadline: None,
        };
        let exact = solve(&p);
        // compare against LPT at the exact solver's chosen J (and all J)
        for j in 1..=counts.iter().sum::<usize>() {
            let k = (total_mb / j).max(1);
            if let Some((_, lpt_min)) = lpt_heuristic(counts, &entity, min_mem, j, k) {
                let lpt_obj = j as f64 * lpt_min;
                let exact_obj = exact.as_ref().map(|s| s.objective).unwrap_or(f64::NEG_INFINITY);
                assert!(
                    exact_obj >= lpt_obj - 1e-9,
                    "case {case}: exact {exact_obj} < lpt {lpt_obj} (j={j}, counts {counts:?}, mem {min_mem:.0})"
                );
            }
        }
    }
}

#[test]
fn partitions_cover_and_respect_memory() {
    let model = ModelCfg::gpt3_6p7b();
    let profile = ProfileDb::build(
        &model,
        &[GpuKind::A100, GpuKind::H800, GpuKind::H20],
        &[1, 2, 4, 8],
        7,
    );
    let kinds = [GpuKind::A100, GpuKind::H800, GpuKind::H20];
    let mut rng = Rng::new(0xF00D);
    for case in 0..CASES {
        let p_stages = 2 + rng.below(6);
        let tp = [1usize, 2, 4, 8][rng.below(4)];
        let stages: Vec<StageRes> = (0..p_stages)
            .map(|_| StageRes { kind: kinds[rng.below(3)], tp })
            .collect();
        if let Some(layers) = partition_layers(&stages, &profile) {
            assert_eq!(
                layers.iter().sum::<usize>(),
                model.n_layers,
                "case {case}: cover"
            );
            assert!(layers.iter().all(|&l| l >= 1), "case {case}: empty stage");
            for (i, (&l, s)) in layers.iter().zip(&stages).enumerate() {
                let cap = s.kind.spec().mem_gib * tp as f64 * f64::powi(2.0, 30) * 0.94;
                let used = profile.mem_bytes(l, i, p_stages, tp, i == 0 || i == p_stages - 1);
                assert!(used <= cap, "case {case} stage {i}: {used:.2e} > {cap:.2e}");
            }
        }
    }
}

#[test]
fn tp_reshard_roundtrips_all_dims() {
    let mut rng = Rng::new(0xAB);
    for name in ["wqkv", "wo", "w1", "b1", "w2", "ln1_g"] {
        for _ in 0..10 {
            let d = 8 * (1 + rng.below(3));
            let full = match name {
                "wqkv" => rand_t(&mut rng, &[d, 3 * d]),
                "wo" => rand_t(&mut rng, &[d, d]),
                "w1" => rand_t(&mut rng, &[d, 4 * d]),
                "b1" => rand_t(&mut rng, &[4 * d]),
                "w2" => rand_t(&mut rng, &[4 * d, d]),
                _ => rand_t(&mut rng, &[d]),
            };
            for tp_old in [1usize, 2, 4] {
                for tp_new in [1usize, 2, 4] {
                    let olds: Vec<HostTensor> = (0..tp_old)
                        .map(|s| shard::split_for_tp(name, &full, tp_old, s).unwrap())
                        .collect();
                    let refs: Vec<&HostTensor> = olds.iter().collect();
                    // reshard to tp_new, then reassemble and compare
                    let news: Vec<HostTensor> = (0..tp_new)
                        .map(|s| shard::reshard(name, &refs, tp_new, s).unwrap())
                        .collect();
                    let nrefs: Vec<&HostTensor> = news.iter().collect();
                    let back = shard::concat_from_shards(name, &nrefs).unwrap();
                    assert_eq!(back, full, "{name} tp {tp_old}->{tp_new}");
                }
            }
        }
    }
}

fn rand_t(rng: &mut Rng, shape: &[usize]) -> HostTensor {
    let mut v = vec![0.0f32; shape.iter().product()];
    rng.fill_normal_f32(&mut v, 1.0);
    HostTensor::from_f32(shape, v)
}

#[test]
fn spot_traces_bounded_and_replayable() {
    for seed in 0..20u64 {
        let t = SpotTrace::generate(TraceConfig::default(), seed);
        for row in &t.avail {
            for (ki, &(_, cap)) in t.cfg.capacity.iter().enumerate() {
                assert!(row[ki] <= cap, "seed {seed}");
            }
        }
        // replay events from the first row and land on the last row
        let mut level: Vec<i64> = t.avail[0].iter().map(|&x| x as i64).collect();
        for ev in t.events() {
            let ki = t.kinds.iter().position(|&k| k == ev.kind).unwrap();
            level[ki] += ev.delta;
            assert!(level[ki] >= 0, "seed {seed}: negative availability");
        }
        let last: Vec<i64> = t.avail.last().unwrap().iter().map(|&x| x as i64).collect();
        assert_eq!(level, last, "seed {seed}");
    }
}
