//! Property tests: spot-market trace generation over *arbitrary*
//! (JSON-defined) GPU catalogs. For random 2–6-kind catalogs,
//! `TraceConfig::from_catalog` must produce traces whose per-kind
//! availability stays within capacity and whose price track stays
//! positive and mean-reverts toward each kind's preset `price_per_hour`.

use autohet::cluster::{GpuCatalog, SpotTrace, TraceConfig};
use autohet::util::json::Json;
use autohet::util::rng::Rng;

/// A random 2–6-kind catalog built through the JSON path (the same door
/// user-defined fleets come through).
fn random_catalog(rng: &mut Rng) -> GpuCatalog {
    let n = 2 + rng.below(5); // 2..=6 kinds
    let kinds: Vec<String> = (0..n)
        .map(|i| {
            format!(
                r#"{{"name": "K{i}", "relative_power": {:.2}, "mem_gib": {}, "price_per_hour": {:.2}}}"#,
                0.5 + rng.f64() * 3.5,
                40 + rng.below(120),
                0.4 + rng.f64() * 7.0
            )
        })
        .collect();
    let doc = format!(r#"{{"kinds": [{}]}}"#, kinds.join(","));
    GpuCatalog::from_json(&Json::parse(&doc).unwrap()).unwrap()
}

#[test]
fn arbitrary_catalog_traces_bounded_and_priced() {
    let mut rng = Rng::new(0xA11C_A7);
    for case in 0..15u64 {
        let cat = random_catalog(&mut rng);
        let cap = 4 + rng.below(12);
        let cfg = TraceConfig::from_catalog(&cat, cap);
        assert_eq!(cfg.capacity.len(), cat.len(), "case {case}");
        assert_eq!(cfg.base_price_per_hour.len(), cat.len(), "case {case}");
        let trace = SpotTrace::generate(cfg, case);

        assert_eq!(trace.kinds.len(), cat.len(), "case {case}");
        assert_eq!(trace.prices.len(), trace.avail.len(), "case {case}");
        for (t, row) in trace.avail.iter().enumerate() {
            for (ki, &(_, kcap)) in trace.cfg.capacity.iter().enumerate() {
                assert!(row[ki] <= kcap, "case {case} step {t}: over capacity");
                assert!(trace.prices[t][ki] > 0.0, "case {case} step {t}: price not positive");
            }
        }

        // the price track reverts toward the preset: its long-run mean
        // stays anchored near base (demand spikes push it slightly above,
        // never toward the multiplier clamps)
        for ki in 0..cat.len() {
            let base = cat.specs()[ki].price_per_hour;
            let mean: f64 =
                trace.prices.iter().map(|r| r[ki]).sum::<f64>() / trace.prices.len() as f64;
            assert!(
                mean > 0.5 * base && mean < 2.0 * base,
                "case {case} kind {ki}: mean price {mean:.3} drifted from preset {base:.3}"
            );
        }

        // batched market events replay into the final availability row
        let mut level: Vec<i64> = trace.avail[0].iter().map(|&x| x as i64).collect();
        for ev in trace.market_events(f64::INFINITY) {
            for (kind, delta) in ev.deltas {
                let ki = trace.kinds.iter().position(|&k| k == kind).unwrap();
                level[ki] += delta;
                assert!(level[ki] >= 0, "case {case}: negative availability");
            }
            assert_eq!(ev.prices.len(), trace.kinds.len(), "case {case}");
        }
        let last: Vec<i64> = trace.avail.last().unwrap().iter().map(|&x| x as i64).collect();
        assert_eq!(level, last, "case {case}");
    }
}

#[test]
fn streaming_events_match_eager_on_arbitrary_catalogs() {
    // `market_events` is a thin `.collect()` over `market_events_iter`;
    // the two surfaces must stay event-for-event identical for any
    // catalog, capacity, seed, and threshold — including the infinite
    // threshold (availability deltas only) and zero (every price tick).
    let mut rng = Rng::new(0x17E8);
    for case in 0..10u64 {
        let cat = random_catalog(&mut rng);
        let cap = 3 + rng.below(10);
        let trace = SpotTrace::generate(TraceConfig::from_catalog(&cat, cap), 200 + case);
        for threshold in [0.0, 0.01, 0.05, 0.3, f64::INFINITY] {
            let eager = trace.market_events(threshold);
            let streamed: Vec<_> = trace.market_events_iter(threshold).collect();
            assert_eq!(eager, streamed, "case {case} threshold {threshold}");
            // and the stream is resumable: a partially drained iterator
            // picks up exactly where it left off
            let mut it = trace.market_events_iter(threshold);
            let head: Vec<_> = it.by_ref().take(2).collect();
            let tail: Vec<_> = it.collect();
            let rejoined: Vec<_> = head.into_iter().chain(tail).collect();
            assert_eq!(eager, rejoined, "case {case} threshold {threshold}: resume broke");
        }
    }
}

#[test]
fn price_reversion_dominates_on_arbitrary_catalogs() {
    // With noise off, every non-spike step must pull the price strictly
    // toward its preset anchor; spikes (the only away-moves) are rare.
    let mut rng = Rng::new(0x5EED);
    for case in 0..8u64 {
        let cat = random_catalog(&mut rng);
        let cfg = TraceConfig {
            price_noise: 0.0,
            spike_prob: 0.05,
            ..TraceConfig::from_catalog(&cat, 8)
        };
        let trace = SpotTrace::generate(cfg, 100 + case);
        let (mut toward, mut away) = (0usize, 0usize);
        for ki in 0..trace.kinds.len() {
            let base = trace.cfg.base_price_of(trace.kinds[ki]);
            for w in trace.prices.windows(2) {
                let (d0, d1) = ((w[0][ki] - base).abs(), (w[1][ki] - base).abs());
                if d1 > d0 + 1e-12 {
                    away += 1;
                } else {
                    toward += 1;
                }
            }
        }
        assert!(
            toward > 3 * away,
            "case {case}: prices not reverting ({toward} toward vs {away} away)"
        );
    }
}
