//! Device-subset (straggler-benching) planning and the price-aware
//! objectives, end to end: on a fleet with a deliberately weak kind the
//! planner must bench it — Eq-3's exact coverage would otherwise force
//! the straggler into some DP group and drag the max–min objective (and
//! the simulated iteration time) down. `docs/PLANNER.md` walks through
//! the same scenario by hand.

use autohet::cluster::{ClusterSpec, GpuCatalog, GpuSpec, KindId, KindVec};
use autohet::modelcfg::ModelCfg;
use autohet::planner::solver::{solve, solve_subsets, EntitySpec, GroupingProblem};
use autohet::planner::{auto_plan, plan_choice, Objective, PlanOptions};
use autohet::profile::ProfileDb;
use autohet::sim::simulate_plan;

/// Built-in catalog plus a pathologically weak (and cheap) spot part.
fn straggler_catalog() -> GpuCatalog {
    let mut cat = GpuCatalog::builtin();
    cat.add(GpuSpec {
        name: "P4".into(),
        relative_power: 0.02,
        flops_tf: 2.8,
        mem_gib: 80.0,
        nvlink_gbs: 300.0,
        hbm_gbs: 900.0,
        price_per_hour: 0.2,
        rdma_nics: 1,
    })
    .unwrap();
    cat
}

fn straggler_fixture() -> (ClusterSpec, ProfileDb, ModelCfg) {
    let cat = straggler_catalog();
    let p4 = cat.lookup("P4").unwrap();
    let cluster = ClusterSpec::from_counts_in(&cat, &[(4, KindId::A100), (1, p4)]);
    let model = ModelCfg::bert_large();
    let profile = ProfileDb::build(&model, &cat, &[1, 2, 4, 8], 1);
    (cluster, profile, model)
}

#[test]
fn benching_straggler_lifts_eq3_min_power() {
    // Hand-checkable instance: 2 strong entities (g = 1.0) + 1 straggler
    // (g = 0.1), memory floor met by any singleton, 8 microbatches.
    let entity = KindVec::from(vec![
        EntitySpec { power: 1.0, mem_gib: 80.0 },
        EntitySpec { power: 0.1, mem_gib: 80.0 },
    ]);
    let p = GroupingProblem {
        counts: KindVec::from(vec![2, 1]),
        entity,
        min_mem_gib: 60.0,
        microbatches_total: 8,
        deadline: None,
    };
    // Exact coverage: best is {A}, {A, W} at J=2 (K=4), where the mixed
    // group's G = 1.1 · (1 − 1/5) = 0.88 → objective 1.76.
    let all = solve(&p).unwrap();
    assert!((all.objective - 1.76).abs() < 1e-9, "{}", all.objective);
    assert!((all.min_g - 0.88).abs() < 1e-9, "{}", all.min_g);
    // Benching the straggler frees two bubble-less singleton groups:
    // min G = 1.0, objective 2 · 1.0 = 2.0 — strictly better.
    let subs = solve_subsets(&p, None);
    let best = &subs[0];
    assert_eq!(best.benched, KindVec::from(vec![0, 1]));
    assert!((best.solution.objective - 2.0).abs() < 1e-9);
    assert!(best.solution.min_g > all.min_g);
}

#[test]
fn benching_beats_all_devices_end_to_end() {
    let (cluster, profile, model) = straggler_fixture();
    let all = auto_plan(&cluster, &profile, &PlanOptions::default()).unwrap();
    let benched = auto_plan(
        &cluster,
        &profile,
        &PlanOptions { bench: true, ..Default::default() },
    )
    .unwrap();
    benched.validate(model.n_layers).unwrap();
    // exact coverage is forced to place the straggler...
    assert_eq!(all.gpu_count(), cluster.total_gpus());
    // ...while the subset planner benches ≥ 1 entity and wins on time
    assert!(
        benched.gpu_count() < cluster.total_gpus(),
        "expected benching, got {}",
        benched.summary(&profile.catalog)
    );
    let (ta, tb) = (
        simulate_plan(&profile, &all).iter_s,
        simulate_plan(&profile, &benched).iter_s,
    );
    assert!(tb < ta, "benched {tb}s should beat all-devices {ta}s");
}

#[test]
fn plan_choice_prices_both_objectives() {
    let (cluster, profile, _) = straggler_fixture();
    let opts = PlanOptions { bench: true, ..Default::default() };
    let choice = plan_choice(&cluster, &profile, &opts).unwrap();
    let (f, c) = (&choice.fastest, &choice.cheapest);
    // the straggler fleet benches under the time objective too
    assert!(f.benched.total() >= 1, "fastest should bench the P4");
    // fastest minimizes sim iter time; cheapest maximizes tokens/$
    assert!(f.plan.est_iter_s <= c.plan.est_iter_s + 1e-12);
    assert!(c.tokens_per_usd >= f.tokens_per_usd - 1e-9);
    // $/iteration accounting uses per-kind price_per_hour of used GPUs
    assert!(f.price_per_hour > 0.0);
    assert!(
        (f.cost_per_iter_usd - f.price_per_hour / 3600.0 * f.plan.est_iter_s).abs() < 1e-12
    );
    assert!(f.eq1_iter_s > 0.0, "Eq-1 estimate is exposed per candidate");
    // objective picking is stable
    assert_eq!(choice.pick(Objective::Time).plan, f.plan);
    assert_eq!(choice.pick(Objective::Cost).plan, c.plan);
}

#[test]
fn subset_planner_never_worse_on_healthy_fleets() {
    // No straggler: benching must not cost anything — the candidate set
    // is a superset, so the fastest plan is at least as fast.
    let cat = GpuCatalog::builtin();
    let model = ModelCfg::llama_7b();
    let profile = ProfileDb::build(&model, &cat, &[1, 2, 4, 8], 1);
    for counts in [
        vec![(4usize, KindId::A100), (2, KindId::H800)],
        vec![(5, KindId::A100), (3, KindId::H800)],
        vec![(2, KindId::A100), (6, KindId::H20)],
    ] {
        let cluster = ClusterSpec::from_counts(&counts);
        let plain = auto_plan(&cluster, &profile, &PlanOptions::default()).unwrap();
        let benched = auto_plan(
            &cluster,
            &profile,
            &PlanOptions { bench: true, ..Default::default() },
        )
        .unwrap();
        assert!(
            benched.est_iter_s <= plain.est_iter_s + 1e-12,
            "{counts:?}: bench made the plan slower"
        );
    }
}
