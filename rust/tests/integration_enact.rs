//! Elastic-equivalence integration over the REAL artifact path: a
//! hand-built 3-event spot trace is enacted end-to-end — real optimizer
//! steps per market segment, layer-wise checkpoint save/load through the
//! tiered store on every replan — and must land within tolerance of the
//! uninterrupted baseline run with identical seeds, with replicas still
//! bit-synced. Plus: two identical enact runs produce bit-identical loss
//! curves, the enactment follows the replay decision log exactly, and a
//! full-fleet pause resumes from the cloud tier alone. Async saves are
//! pinned bit-identical to the synchronous path at worker counts 1/2/8
//! (loss curves, decision log, sim-time meters), and codec compression
//! must never perturb training.
//!
//! All tests skip (with a notice) until the AOT artifacts exist
//! (`cd python && python -m compile.aot --preset tiny --out-dir ../rust/artifacts`).

use std::path::{Path, PathBuf};

use autohet::checkpoint::Codec;
use autohet::cluster::{GpuCatalog, KindId, SpotTrace, TraceConfig};
use autohet::modelcfg::ModelCfg;
use autohet::profile::ProfileDb;
use autohet::recovery::{
    baseline_train, enact, replay, EnactConfig, ReplanDecision, ReplayConfig,
};
use autohet::runtime::Engine;
use autohet::train::AdamConfig;

fn tiny_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny")
}

fn engine() -> Option<Engine> {
    if !tiny_dir().join("manifest.json").exists() {
        eprintln!("SKIP: run the AOT compile first (python -m compile.aot)");
        return None;
    }
    Some(Engine::load(&tiny_dir()).unwrap())
}

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "ah-enact-{tag}-{}-{}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn profile() -> ProfileDb {
    ProfileDb::build(&ModelCfg::bert_large(), &GpuCatalog::builtin(), &[1, 2, 4, 8], 1)
}

/// A trace with hand-built availability so it yields EXACTLY three
/// market events: preempt 4×H800, preempt 2×A100, grant 2×A100. Prices
/// are flat so no price-only events fire.
fn three_event_trace() -> SpotTrace {
    let tc = TraceConfig {
        horizon_s: 6.0 * 600.0,
        step_s: 600.0,
        capacity: vec![(KindId::A100, 6), (KindId::H800, 4)],
        base_price_per_hour: vec![(KindId::A100, 1.2), (KindId::H800, 2.5)],
        ..Default::default()
    };
    let kinds: Vec<KindId> = tc.capacity.iter().map(|&(k, _)| k).collect();
    SpotTrace {
        kinds,
        avail: vec![
            vec![6, 4],
            vec![6, 4],
            vec![6, 0], // event 1: all H800 preempted
            vec![6, 0],
            vec![4, 0], // event 2: 2×A100 preempted
            vec![6, 0], // event 3: 2×A100 granted back
        ],
        prices: vec![vec![1.2, 2.5]; 6],
        cfg: tc,
        seed: 0,
    }
}

/// A trace that kills the whole fleet then grants a fresh one, forcing a
/// pause + cloud-only resume.
fn pause_resume_trace() -> SpotTrace {
    let tc = TraceConfig {
        horizon_s: 5.0 * 600.0,
        step_s: 600.0,
        capacity: vec![(KindId::A100, 6), (KindId::H800, 4)],
        base_price_per_hour: vec![(KindId::A100, 1.2), (KindId::H800, 2.5)],
        ..Default::default()
    };
    let kinds: Vec<KindId> = tc.capacity.iter().map(|&(k, _)| k).collect();
    SpotTrace {
        kinds,
        avail: vec![
            vec![6, 4],
            vec![6, 4],
            vec![0, 0], // everything preempted -> pause
            vec![0, 0],
            vec![4, 0], // fresh grant -> resume from cloud
        ],
        prices: vec![vec![1.2, 2.5]; 5],
        cfg: tc,
        seed: 0,
    }
}

fn cfg(tag: &str) -> EnactConfig {
    EnactConfig {
        replay: ReplayConfig::default(),
        steps_per_event: 4,
        k_per_group: 2,
        max_groups: 2,
        adam: AdamConfig { lr: 2e-3, ..Default::default() },
        seed: 7,
        ckpt_dir: tmp(tag),
        ckpt_workers: 0,
        ckpt_codec: Codec::Raw,
    }
}

#[test]
fn three_event_enactment_is_loss_equivalent_to_uninterrupted() {
    let Some(e) = engine() else { return };
    let p = profile();
    let trace = three_event_trace();
    let c = cfg("equiv");

    let report = enact(&e, &p, &trace, &c).unwrap();
    assert_eq!(report.rows.len(), 3, "the trace must yield exactly 3 events");
    assert!(report.switches >= 1, "losing all H800s must force a migration");
    assert!(report.steps >= 3 * c.steps_per_event, "paused too much: {}", report.steps);
    assert!(report.replicas_synced, "replicas diverged through the enactment");
    assert!(report.final_eval_loss.is_finite());

    // the uninterrupted oracle: identical seeds, same number of real steps
    let (base_losses, base_eval) =
        baseline_train(&e, &[vec![e.manifest.dims.n_layers]], report.steps, &c).unwrap();
    assert_eq!(base_losses.len(), report.steps);
    let diff = (report.final_eval_loss - base_eval).abs();
    assert!(
        diff < 0.3,
        "enacted {:.4} vs uninterrupted {:.4}: |Δ| = {diff:.4}",
        report.final_eval_loss,
        base_eval
    );
    // both runs actually trained (eval below the untrained starting point)
    let (_, init_eval) = baseline_train(&e, &[vec![e.manifest.dims.n_layers]], 0, &c).unwrap();
    assert!(report.final_eval_loss < init_eval, "{} !< {init_eval}", report.final_eval_loss);
    assert!(base_eval < init_eval);
}

#[test]
fn enactment_follows_the_replay_decision_log_and_reconciles_bytes() {
    let Some(e) = engine() else { return };
    let p = profile();
    let trace = three_event_trace();
    let c = cfg("log");

    let log = replay(&p, &trace, &c.replay).unwrap();
    let report = enact(&e, &p, &trace, &c).unwrap();
    assert!(
        report.matches_decision_log(&log),
        "enactment diverged from the replay decision log:\n{:?}\nvs\n{:?}",
        report.rows.iter().map(|r| (r.at_s, r.decision, r.forced)).collect::<Vec<_>>(),
        log.rows.iter().map(|r| (r.at_s, r.decision, r.forced)).collect::<Vec<_>>()
    );

    // every enacted migration's byte counters feed the Fig-10 model:
    // fractions partition the measured bytes, and the timing model prices
    // them to a positive recovery time
    let mut loads = 0;
    for r in &report.rows {
        if let Some(load) = &r.load {
            loads += 1;
            assert!(load.total_bytes() > 0);
            assert!(
                (r.local_frac + r.peer_frac + r.cloud_frac - 1.0).abs() < 1e-9,
                "fractions must partition the load: {r:?}"
            );
            assert!(r.timing_model_s > 0.0);
        }
        // a checkpoint is written at every event the run was live for
        if r.steps_run > 0 {
            assert!(r.save.bytes_local > 0 && r.save.bytes_cloud > 0);
            assert_eq!(r.save.bytes_local, r.save.bytes_cloud);
        }
    }
    assert!(loads >= 1, "no real restore was exercised");

    // CSV surface: header + one line per event, fixed column count
    let csv = report.to_csv();
    let lines: Vec<&str> = csv.lines().collect();
    assert_eq!(lines.len(), report.rows.len() + 1);
    let cols = lines[0].matches(',').count();
    for l in &lines[1..] {
        assert_eq!(l.matches(',').count(), cols, "{l}");
    }
}

#[test]
fn two_identical_enact_runs_are_bit_identical() {
    let Some(e) = engine() else { return };
    let p = profile();
    let trace = three_event_trace();

    let a = enact(&e, &p, &trace, &cfg("det-a")).unwrap();
    let b = enact(&e, &p, &trace, &cfg("det-b")).unwrap();
    assert_eq!(a.losses, b.losses, "loss curves must be bit-identical");
    assert_eq!(a.final_eval_loss, b.final_eval_loss);
    assert_eq!(a.steps, b.steps);
    assert_eq!(a.switches, b.switches);
    assert_eq!(
        a.rows.iter().map(|r| r.decision).collect::<Vec<_>>(),
        b.rows.iter().map(|r| r.decision).collect::<Vec<_>>()
    );
    assert_eq!(a.bytes_loaded_cloud, b.bytes_loaded_cloud);
}

#[test]
fn full_fleet_pause_resumes_from_cloud_only() {
    let Some(e) = engine() else { return };
    let p = profile();
    let trace = pause_resume_trace();
    let c = cfg("pause");

    let report = enact(&e, &p, &trace, &c).unwrap();
    assert_eq!(report.pauses, 1);
    let pause = report
        .rows
        .iter()
        .find(|r| r.decision == ReplanDecision::Paused)
        .expect("no pause row");
    // the pre-pause checkpoint made it to the cloud before the fleet died
    assert!(pause.save.bytes_cloud > 0);

    let resume = report
        .rows
        .iter()
        .find(|r| r.decision == ReplanDecision::Switched && r.load.is_some())
        .expect("no resume row");
    assert_eq!(resume.steps_run, 0, "nothing trains while paused");
    let load = resume.load.as_ref().unwrap();
    assert!(load.bytes_cloud > 0, "resume must pull from the cloud");
    assert_eq!(
        load.bytes_memory + load.bytes_disk + load.bytes_rdma,
        0,
        "no local tier survives a full-fleet preemption: {load:?}"
    );
    assert!((resume.cloud_frac - 1.0).abs() < 1e-9);
    // training continues after the resume: the pre-pause interval plus
    // the post-resume tail train, the paused interval does not
    assert_eq!(report.steps, 2 * c.steps_per_event);
    assert!(report.replicas_synced);
}

#[test]
fn async_enact_is_bit_identical_to_sync_at_any_worker_count() {
    let Some(e) = engine() else { return };
    let p = profile();
    let trace = three_event_trace();

    let sync = enact(&e, &p, &trace, &cfg("async-0")).unwrap();
    assert_eq!(sync.save_bg_wall_s, 0.0, "sync mode hides nothing");
    assert_eq!(sync.save_overlap_ratio(), 0.0);
    for workers in [1usize, 2, 8] {
        let mut c = cfg(&format!("async-{workers}"));
        c.ckpt_workers = workers;
        let r = enact(&e, &p, &trace, &c).unwrap();
        // the real loss curve is bit-identical: background encode+commit
        // must not perturb a single optimizer step
        assert_eq!(r.losses, sync.losses, "workers={workers}");
        assert_eq!(
            r.final_eval_loss.to_bits(),
            sync.final_eval_loss.to_bits(),
            "workers={workers}"
        );
        // same decision trail
        assert_eq!(
            r.rows.iter().map(|x| (x.decision, x.forced)).collect::<Vec<_>>(),
            sync.rows.iter().map(|x| (x.decision, x.forced)).collect::<Vec<_>>(),
            "workers={workers}"
        );
        // sim-time meters are f64 sums over store ops — bit equality
        // proves the op order matched the synchronous path exactly
        assert_eq!(r.save_sim_s.to_bits(), sync.save_sim_s.to_bits(), "workers={workers}");
        assert_eq!(r.load_sim_s.to_bits(), sync.load_sim_s.to_bits(), "workers={workers}");
        assert_eq!(r.bytes_saved_local, sync.bytes_saved_local, "workers={workers}");
        assert_eq!(r.bytes_saved_raw, sync.bytes_saved_raw, "workers={workers}");
        assert_eq!(r.bytes_loaded_cloud, sync.bytes_loaded_cloud, "workers={workers}");
        // per-row commit results were backfilled under the right tags
        for (x, y) in r.rows.iter().zip(&sync.rows) {
            assert_eq!(x.save.bytes_local, y.save.bytes_local, "workers={workers}");
            assert_eq!(x.save.units, y.save.units, "workers={workers}");
        }
    }
}

#[test]
fn async_timing_model_matches_sync_bit_for_bit() {
    let Some(e) = engine() else { return };
    let p = profile();
    let trace = three_event_trace();

    let mut sc = cfg("ratio-sync");
    sc.ckpt_codec = Codec::Delta;
    let sync = enact(&e, &p, &trace, &sc).unwrap();
    let mut ac = cfg("ratio-async");
    ac.ckpt_codec = Codec::Delta;
    ac.ckpt_workers = 4;
    let bg = enact(&e, &p, &trace, &ac).unwrap();

    assert_eq!(bg.rows.len(), sync.rows.len());
    for (a, s) in bg.rows.iter().zip(&sync.rows) {
        // the Fig-10 recovery estimate prices the *measured* compression
        // ratio of the checkpoint it restores; backgrounding the save
        // must not shift either by a single bit
        assert_eq!(a.timing_model_s.to_bits(), s.timing_model_s.to_bits(), "at {}s", a.at_s);
        assert_eq!(a.save_ratio.to_bits(), s.save_ratio.to_bits(), "at {}s", a.at_s);
        // and the reported ratio is the committed save's own, never a
        // stale or default one
        if a.save.bytes_raw > 0 {
            assert_eq!(a.save_ratio, a.save.compression_ratio(), "at {}s", a.at_s);
        }
        assert!(a.save_ratio > 0.0 && a.save_ratio.is_finite(), "at {}s", a.at_s);
    }
    // the invariant is vacuous unless some row actually committed bytes
    assert!(sync.rows.iter().any(|r| r.save.bytes_raw > 0), "no save committed");
}

#[test]
fn codec_compression_never_perturbs_training() {
    let Some(e) = engine() else { return };
    let p = profile();
    let trace = three_event_trace();

    let raw = enact(&e, &p, &trace, &cfg("codec-raw")).unwrap();
    let mut c = cfg("codec-delta");
    c.ckpt_codec = Codec::Delta;
    c.ckpt_workers = 2;
    let r = enact(&e, &p, &trace, &c).unwrap();
    // compression changes bytes on the wire, never the training path
    assert_eq!(r.losses, raw.losses);
    assert_eq!(r.final_eval_loss.to_bits(), raw.final_eval_loss.to_bits());
    assert!(r.replicas_synced);
    // the raw payload is codec-invariant; framed bytes stay within the
    // header ceiling and the Fig-10 model never prices compressed bytes
    // above the raw run
    assert_eq!(r.bytes_saved_raw, raw.bytes_saved_raw);
    assert!(r.bytes_saved_raw > 0);
    assert!(
        r.bytes_saved_local <= raw.bytes_saved_local + 64 * 1024,
        "framed {} vs raw-run {}",
        r.bytes_saved_local,
        raw.bytes_saved_local
    );
    for (x, y) in r.rows.iter().zip(&raw.rows) {
        if x.load.is_some() {
            assert!(
                x.timing_model_s <= y.timing_model_s + 1e-9,
                "compressed recovery must not price above raw: {} vs {}",
                x.timing_model_s,
                y.timing_model_s
            );
        }
    }
}
