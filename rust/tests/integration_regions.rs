//! End-to-end regional spot markets: a correlated storm kills every GPU
//! kind in the home region at once and the fleet must re-form in a
//! foreign region from cloud checkpoints alone, paying egress on every
//! moved byte — while a single-region map stays bit-identical to the
//! region-free replay engine.

use autohet::cluster::{
    GpuCatalog, Interconnect, KindId, RegionId, RegionMap, RegionSpec, RegionalTrace, SpotTrace,
    TraceConfig,
};
use autohet::modelcfg::ModelCfg;
use autohet::profile::ProfileDb;
use autohet::recovery::{
    cross_region_migration, replay, replay_regions, ReplanDecision, ReplayConfig,
};

fn profile() -> ProfileDb {
    ProfileDb::build(&ModelCfg::bert_large(), &GpuCatalog::builtin(), &[1, 2, 4, 8], 1)
}

fn base_trace_cfg(hours: f64) -> TraceConfig {
    TraceConfig {
        horizon_s: hours * 3600.0,
        step_s: 1800.0,
        capacity: vec![(KindId::A100, 6), (KindId::H800, 4)],
        ..Default::default()
    }
}

#[test]
fn regional_storm_relocates_the_fleet_via_cloud_checkpoints() {
    // the classic failure story: region `doomed` is hit by a permanent
    // correlated storm (every kind dark from step 0), region `haven`
    // stays calm — the run must relocate, restore cloud-tier-only, and
    // bill egress on the checkpoint bytes that crossed the region line
    let profile = profile();
    let map = RegionMap {
        regions: vec![
            RegionSpec {
                name: "doomed".into(),
                storm_prob: 1.0,
                storm_sev: 1.0,
                storm_len: 100_000,
                ..Default::default()
            },
            RegionSpec { name: "haven".into(), price_mult: 1.05, ..Default::default() },
        ],
        egress_usd_per_gb: vec![vec![0.0, 0.08], vec![0.08, 0.0]],
    };
    let rt = RegionalTrace::generate(&base_trace_cfg(12.0), &map, 3).unwrap();
    // the storm is region-wide and correlated: every kind, every step
    assert!(
        rt.traces[0].avail.iter().flatten().all(|&a| a == 0),
        "storm region still has capacity"
    );
    assert!(rt.traces[1].avail.iter().flatten().sum::<usize>() > 0, "haven went dark");

    let report = replay_regions(&profile, &rt, &ReplayConfig::default()).unwrap();
    assert!(report.relocations >= 1, "storm never forced a relocation");
    assert_eq!(report.final_region, "haven");
    assert!(report.egress_usd > 0.0, "relocation billed no egress");
    assert!(report.tokens > 0.0, "the fleet never re-formed and trained");

    let reloc = report
        .rows
        .iter()
        .find(|r| r.reason.contains("relocated"))
        .expect("no relocation row in the decision log");
    assert_eq!(reloc.decision, ReplanDecision::Switched);
    assert_eq!(reloc.region, "haven");
    assert!(reloc.forced, "a dead home region must force the move");
    assert!(reloc.migration_s > 0.0, "cloud-only restore cannot be free");
    assert!(reloc.egress_usd > 0.0);
    assert!(
        reloc.reason.contains("cloud-only restore"),
        "relocation must restore from the cloud tier: {}",
        reloc.reason
    );
    // egress is billed into the run's spend meter, not alongside it
    assert!(report.usd >= report.egress_usd);
}

#[test]
fn single_region_map_is_bit_identical_to_region_free_replay() {
    // the regional engine with one default region IS the old engine:
    // same rows, same meters, to the bit — at several seeds
    let profile = profile();
    for seed in [1u64, 9, 42] {
        let tc = base_trace_cfg(24.0);
        let solo_trace = SpotTrace::generate(tc.clone(), seed);
        let solo = replay(&profile, &solo_trace, &ReplayConfig::default()).unwrap();
        let rt = RegionalTrace::generate(&tc, &RegionMap::single(), seed).unwrap();
        let regional = replay_regions(&profile, &rt, &ReplayConfig::default()).unwrap();

        assert_eq!(regional.tokens.to_bits(), solo.tokens.to_bits(), "seed {seed}");
        assert_eq!(regional.usd.to_bits(), solo.usd.to_bits(), "seed {seed}");
        assert_eq!(regional.downtime_s.to_bits(), solo.downtime_s.to_bits(), "seed {seed}");
        assert_eq!(regional.switches, solo.switches, "seed {seed}");
        assert_eq!(regional.holds, solo.holds, "seed {seed}");
        assert_eq!(regional.relocations, 0);
        assert_eq!(regional.egress_usd, 0.0);
        assert_eq!(regional.final_region, "local");
        assert_eq!(regional.rows.len(), solo.rows.len(), "seed {seed}");
        for (a, b) in regional.rows.iter().zip(&solo.rows) {
            assert_eq!(a.decision, b.decision);
            assert_eq!(a.at_s.to_bits(), b.at_s.to_bits());
            assert_eq!(a.region, "local");
            assert_eq!(a.egress_usd, 0.0);
        }
    }
}

#[test]
fn cross_region_restore_is_cloud_only_and_egress_priced() {
    // the Fig-10 pricing of a relocation: every checkpoint byte comes
    // from the cloud tier (nothing local survives a region move) and the
    // egress bill is exactly rate x moved GB
    let model = ModelCfg::bert_large();
    let mig = cross_region_migration(&model, 2, 2, &Interconnect::default(), 0.08);
    assert!(mig.bytes_cloud > 0.0, "cross-region restore must pull from cloud");
    assert!(mig.downtime_s > 0.0);
    let expect = mig.bytes_cloud / 1e9 * 0.08;
    assert!((mig.egress_usd - expect).abs() < 1e-9, "{} vs {}", mig.egress_usd, expect);
    // free-egress regions still pay the restore downtime
    let free = cross_region_migration(&model, 2, 2, &Interconnect::default(), 0.0);
    assert_eq!(free.egress_usd, 0.0);
    assert!(free.downtime_s > 0.0);
}

#[test]
fn bundled_regions_example_parses_and_validates() {
    // the map the README/CI quickstart points at must stay loadable
    let path = if std::path::Path::new("examples/regions.json").exists() {
        std::path::PathBuf::from("examples/regions.json")
    } else {
        std::path::Path::new("..").join("examples/regions.json")
    };
    let map =
        RegionMap::from_json(&autohet::util::json::Json::parse_file(&path).unwrap()).unwrap();
    assert!(map.len() >= 2, "example should exercise a multi-region market");
    map.validate().unwrap();
    for r in 0..map.len() {
        assert_eq!(map.egress(RegionId(r), RegionId(r)), 0.0);
    }
}
