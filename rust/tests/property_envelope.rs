//! Property tests for the budget/deadline envelope (the paper's actual
//! spot operating regime: "spend at most $X by time T").
//!
//! For random traces and randomly drawn envelopes, a budget-capped
//! replay must (a) never report more dollars than the cap (+ float ε),
//! (b) never attribute a second of training/downtime/pause past the
//! deadline, and (c) with an unbounded envelope (including the
//! `max_usd = ∞` form) reproduce the unconstrained replay bit-for-bit.

use autohet::cluster::{GpuCatalog, KindId, SpotTrace, TraceConfig};
use autohet::modelcfg::ModelCfg;
use autohet::planner::{BudgetEnvelope, PlanOptions};
use autohet::profile::ProfileDb;
use autohet::recovery::{replay, ReplanDecision, ReplayConfig};
use autohet::util::rng::Rng;

fn profile() -> ProfileDb {
    ProfileDb::build(&ModelCfg::bert_large(), &GpuCatalog::builtin(), &[1, 2, 4, 8], 1)
}

fn trace(seed: u64, hours: f64) -> SpotTrace {
    let tc = TraceConfig {
        horizon_s: hours * 3600.0,
        step_s: 1800.0,
        capacity: vec![(KindId::A100, 8), (KindId::H800, 4), (KindId::H20, 4)],
        base_price_per_hour: vec![
            (KindId::A100, 1.2),
            (KindId::H800, 2.5),
            (KindId::H20, 0.9),
        ],
        ..Default::default()
    };
    SpotTrace::generate(tc, seed)
}

#[test]
fn capped_replay_never_overspends_or_overruns() {
    let p = profile();
    let mut rng = Rng::new(0xB0D6E7);
    let eps = 1e-6;
    for seed in 0..6u64 {
        let trace = trace(seed, 12.0);
        let free = replay(&p, &trace, &ReplayConfig::default()).unwrap();
        assert!(free.usd > 0.0, "seed {seed}: free run billed nothing");

        // a random envelope strictly inside what the free run consumed,
        // so at least one axis genuinely binds
        let env = BudgetEnvelope {
            max_usd: Some(free.usd * (0.1 + 0.6 * rng.f64())),
            deadline_s: Some(free.horizon_s * (0.2 + 0.6 * rng.f64())),
        };
        let cfg = ReplayConfig {
            envelope: env,
            opts: PlanOptions { bench: true, ..Default::default() },
            ..Default::default()
        };
        let r = replay(&p, &trace, &cfg).unwrap();

        // (a) the cap is a hard ceiling
        let cap = env.max_usd.unwrap();
        assert!(r.usd <= cap + eps, "seed {seed}: spent {} over cap {cap}", r.usd);
        for row in &r.rows {
            assert!(row.usd_total <= cap + eps, "seed {seed}: row over cap: {row:?}");
        }

        // (b) not a second is attributed past the deadline
        let deadline = env.deadline_s.unwrap();
        let attributed = r.train_s + r.downtime_s + r.paused_s;
        assert!(
            attributed <= deadline.min(r.horizon_s) + eps,
            "seed {seed}: {attributed}s attributed past deadline {deadline}s"
        );

        // slack bookkeeping agrees with the meters
        assert!((r.budget_slack_usd.unwrap() - (cap - r.usd)).abs() < 1e-9);
        assert!(r.deadline_slack_s.unwrap() >= -eps);

        // an exhausted run ends in exactly one terminal row
        let terminal: Vec<_> = r
            .rows
            .iter()
            .filter(|row| row.decision == ReplanDecision::BudgetExhausted)
            .collect();
        if r.exhausted {
            assert_eq!(terminal.len(), 1, "seed {seed}");
            let last = r.rows.last().unwrap();
            assert_eq!(last.decision, ReplanDecision::BudgetExhausted);
            assert!(last.forced);
            assert_eq!(last.iter_s, 0.0);
            assert_eq!(last.price_per_hour, 0.0);
        } else {
            assert!(terminal.is_empty(), "seed {seed}");
        }

        // the cap is strictly inside the free run's spend, so the capped
        // run necessarily bills less than the unconstrained one
        assert!(r.usd < free.usd, "seed {seed}: {} !< {}", r.usd, free.usd);
    }
}

#[test]
fn unbounded_envelope_is_bit_identical_to_unconstrained() {
    let p = profile();
    for seed in [3u64, 9, 21] {
        let trace = trace(seed, 10.0);
        let a = replay(&p, &trace, &ReplayConfig::default()).unwrap();
        // the issue's `max_usd = ∞` form: an infinite bound must be as
        // inert as no bound at all
        let cfg = ReplayConfig {
            envelope: BudgetEnvelope { max_usd: Some(f64::INFINITY), deadline_s: None },
            ..Default::default()
        };
        let b = replay(&p, &trace, &cfg).unwrap();
        assert_eq!(a.tokens.to_bits(), b.tokens.to_bits(), "seed {seed}");
        assert_eq!(a.usd.to_bits(), b.usd.to_bits(), "seed {seed}");
        assert_eq!(a.train_s.to_bits(), b.train_s.to_bits(), "seed {seed}");
        assert_eq!(a.downtime_s.to_bits(), b.downtime_s.to_bits(), "seed {seed}");
        assert_eq!(a.paused_s.to_bits(), b.paused_s.to_bits(), "seed {seed}");
        assert_eq!(a.switches, b.switches);
        assert_eq!(a.holds, b.holds);
        assert_eq!(a.unchanged, b.unchanged);
        assert_eq!(a.rows.len(), b.rows.len());
        for (ra, rb) in a.rows.iter().zip(&b.rows) {
            assert_eq!(ra.decision, rb.decision);
            assert_eq!(ra.forced, rb.forced);
            assert_eq!(ra.at_s.to_bits(), rb.at_s.to_bits());
            assert_eq!(ra.tokens_total.to_bits(), rb.tokens_total.to_bits());
            assert_eq!(ra.usd_total.to_bits(), rb.usd_total.to_bits());
        }
        assert!(!b.exhausted);
        // the infinite cap still reports its (infinite) slack
        assert_eq!(b.budget_slack_usd, Some(f64::INFINITY));
        assert_eq!(a.budget_slack_usd, None);
        assert_eq!(a.deadline_slack_s, None);
    }
}

#[test]
fn deadline_alone_stops_the_run_at_the_deadline() {
    let p = profile();
    let trace = trace(7, 12.0);
    let deadline = trace.covered_s() * 0.5;
    let cfg = ReplayConfig {
        envelope: BudgetEnvelope { max_usd: None, deadline_s: Some(deadline) },
        ..Default::default()
    };
    let r = replay(&p, &trace, &cfg).unwrap();
    assert!(r.exhausted, "a mid-horizon deadline must end the run early");
    let last = r.rows.last().unwrap();
    assert_eq!(last.decision, ReplanDecision::BudgetExhausted);
    assert!((last.at_s - deadline).abs() < 1e-9, "{} vs {deadline}", last.at_s);
    assert!(last.reason.contains("deadline"), "{}", last.reason);
    assert!(r.train_s + r.downtime_s + r.paused_s <= deadline + 1e-6);
    assert_eq!(r.budget_slack_usd, None);
    assert!((r.deadline_slack_s.unwrap()).abs() < 1e-9);
}

#[test]
fn tight_budget_emits_the_cap_reason_and_stops_billing() {
    let p = profile();
    let trace = trace(11, 12.0);
    let free = replay(&p, &trace, &ReplayConfig::default()).unwrap();
    // so tight the very first billed interval crosses it
    let cap = free.usd * 0.01;
    let cfg = ReplayConfig {
        envelope: BudgetEnvelope { max_usd: Some(cap), deadline_s: None },
        ..Default::default()
    };
    let r = replay(&p, &trace, &cfg).unwrap();
    assert!(r.usd <= cap + 1e-9);
    assert!(r.exhausted, "1% of the free spend must exhaust");
    let last = r.rows.last().unwrap();
    assert!(last.reason.contains("budget cap"), "{}", last.reason);
    // the meter stopped exactly at the cap (the run was billing when it hit)
    assert!((r.usd - cap).abs() < 1e-6, "{} vs {cap}", r.usd);
}
