//! Catalog integration: the dynamic `GpuCatalog` must be plan-invisible
//! for the paper's three parts — on the Fig 7/8 cluster configs the
//! in-code built-in, an explicit `from_counts_in` copy, and a
//! JSON-round-tripped catalog must yield identical plans (seed *solver*
//! semantics are pinned separately by the retained solver/grouping unit
//! tests) — and fully open for new fleets (end-to-end planning on a
//! 5-kind catalog defined purely in a JSON document).

use autohet::cluster::{ClusterSpec, GpuCatalog, KindId};
use autohet::modelcfg::ModelCfg;
use autohet::planner::{auto_plan, ParallelPlan, PlanOptions};
use autohet::profile::ProfileDb;
use autohet::sim::simulate_plan;
use autohet::util::json::Json;

/// Strip wall-clock noise so plans compare structurally.
fn canon(mut p: ParallelPlan) -> ParallelPlan {
    p.planning_s = 0.0;
    p
}

fn plan_for(cluster: &ClusterSpec, model: &ModelCfg) -> Option<ParallelPlan> {
    let profile = ProfileDb::build(model, &cluster.catalog, &[1, 2, 4, 8], 1);
    auto_plan(cluster, &profile, &PlanOptions::default())
        .ok()
        .map(canon)
}

/// The Fig 7 (uniform) and Fig 8 (non-uniform) cluster configs.
fn figure_configs() -> Vec<(ModelCfg, Vec<(usize, KindId)>)> {
    let mut out = Vec::new();
    // Fig 7: uniform H800+A100 and A100+H20 at 2/4/8 GPUs per node
    for model in [ModelCfg::bert_large(), ModelCfg::gpt3_6p7b()] {
        for (ka, kb) in [(KindId::H800, KindId::A100), (KindId::A100, KindId::H20)] {
            for per_node in [2usize, 4, 8] {
                out.push((model.clone(), vec![(per_node, ka), (per_node, kb)]));
            }
        }
    }
    // Fig 8: non-uniform LLaMA-6.7B fleets
    for counts in [
        vec![(4, KindId::A100), (2, KindId::H800)],
        vec![(5, KindId::A100), (3, KindId::H800)],
        vec![(3, KindId::A100), (5, KindId::H800)],
        vec![(6, KindId::A100), (2, KindId::H800)],
        vec![(1, KindId::A100), (4, KindId::H20)],
        vec![(2, KindId::A100), (6, KindId::H20)],
        vec![(1, KindId::A100), (7, KindId::H20)],
        vec![(3, KindId::A100), (5, KindId::H20)],
    ] {
        out.push((ModelCfg::llama_7b(), counts));
    }
    out
}

#[test]
fn builtin_catalog_reproduces_plans_via_json_round_trip() {
    // Parity: planning over the built-in catalog must produce bit-equal
    // plans whether the catalog is the in-code built-in, an explicit
    // `from_counts_in` copy, or a catalog parsed back from its own JSON —
    // i.e. the registry machinery adds zero behavioral drift on the
    // paper's Fig 7/8 evaluation grid.
    let mut compared = 0;
    for (model, counts) in figure_configs() {
        let direct = ClusterSpec::from_counts(&counts);
        let Some(p_direct) = plan_for(&direct, &model) else {
            continue; // config infeasible for this model: nothing to compare
        };
        compared += 1;

        let explicit = ClusterSpec::from_counts_in(&GpuCatalog::builtin(), &counts);
        assert_eq!(
            Some(&p_direct),
            plan_for(&explicit, &model).as_ref(),
            "{counts:?} explicit"
        );

        // serialize cluster (catalog included) -> parse -> replan
        let doc = direct.to_json().to_string();
        let parsed = ClusterSpec::from_json(&Json::parse(&doc).unwrap()).unwrap();
        assert_eq!(parsed, direct, "{counts:?}: cluster JSON round trip");
        assert_eq!(
            Some(&p_direct),
            plan_for(&parsed, &model).as_ref(),
            "{counts:?} via JSON"
        );
    }
    assert!(compared >= 10, "only {compared} feasible Fig 7/8 configs");
}

#[test]
fn five_kind_catalog_plans_end_to_end_from_json() {
    // A synthetic 5-kind fleet defined entirely in JSON: three bundled
    // presets referenced by name plus two fully custom kinds.
    let doc = r#"{
        "catalog": {"kinds": [
            {"name": "A100"},
            {"name": "H800"},
            {"name": "B200"},
            {"name": "Volta2", "relative_power": 0.7, "mem_gib": 64,
             "flops_tf": 98.0, "nvlink_gbs": 300.0, "hbm_gbs": 900.0},
            {"name": "Custom-XL", "relative_power": 3.0, "mem_gib": 128}
        ]},
        "nodes": [
            {"node_id": 0, "count": 4, "kind": "A100"},
            {"node_id": 1, "count": 4, "kind": "H800"},
            {"node_id": 2, "count": 2, "kind": "B200"},
            {"node_id": 3, "count": 4, "kind": "Volta2"},
            {"node_id": 4, "count": 2, "kind": "Custom-XL"}
        ],
        "rdma_gbs": 50.0
    }"#;
    let cluster = ClusterSpec::from_json(&Json::parse(doc).unwrap()).unwrap();
    assert_eq!(cluster.catalog.len(), 5);
    assert_eq!(cluster.total_gpus(), 16);

    let model = ModelCfg::gpt3_6p7b();
    let profile = ProfileDb::build(&model, &cluster.catalog, &[1, 2, 4, 8], 1);
    let plan = auto_plan(&cluster, &profile, &PlanOptions::default()).unwrap();
    plan.validate(model.n_layers).unwrap();
    assert_eq!(plan.gpu_count(), 16, "exact cover of the 5-kind fleet");

    // every registered kind that exists in the fleet appears in the plan
    let mut kinds_used: Vec<KindId> = plan
        .groups
        .iter()
        .flat_map(|g| g.stages.iter().map(|s| s.kind))
        .collect();
    kinds_used.sort();
    kinds_used.dedup();
    assert_eq!(kinds_used.len(), 5, "{:?}", plan.summary(&cluster.catalog));

    // and the simulator runs on it
    let stats = simulate_plan(&profile, &plan);
    assert!(stats.tokens_per_s > 0.0 && stats.iter_s > 0.0);
}

#[test]
fn extended_presets_plan_out_of_the_box() {
    // B200/L40S/MI300X presets are planner-ready without any JSON.
    let cat = GpuCatalog::extended();
    let b200 = cat.lookup("B200").unwrap();
    let mi300x = cat.lookup("MI300X").unwrap();
    let cluster = ClusterSpec::from_counts_in(&cat, &[(4, b200), (4, mi300x)]);
    let model = ModelCfg::gpt3_6p7b();
    let profile = ProfileDb::build(&model, &cat, &[1, 2, 4, 8], 1);
    let plan = auto_plan(&cluster, &profile, &PlanOptions::default()).unwrap();
    plan.validate(model.n_layers).unwrap();
    assert_eq!(plan.gpu_count(), 8);
}
