//! Elastic-recovery integration over the REAL artifact path: train,
//! checkpoint layer-wise, preempt (wipe volatile state, change the
//! parallelization plan), recover, verify bit-identical state and that
//! training continues from where it left off. Skips without artifacts.

use std::path::{Path, PathBuf};

use autohet::checkpoint::CheckpointManager;
use autohet::pipeline::{ExecTopology, PipelineTrainer};
use autohet::runtime::{Engine, HostTensor};
use autohet::train::{AdamConfig, MarkovCorpus};

fn tiny_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny")
}

fn engine() -> Option<Engine> {
    if !tiny_dir().join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return None;
    }
    Some(Engine::load(&tiny_dir()).unwrap())
}

fn tmp(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "ah-rec-{tag}-{}-{}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn batches(
    corpus: &mut MarkovCorpus,
    dims: autohet::runtime::ModelDims,
    groups: usize,
    k: usize,
) -> Vec<Vec<(HostTensor, HostTensor)>> {
    (0..groups)
        .map(|_| {
            (0..k)
                .map(|_| {
                    let (t, g) = corpus.next_batch(dims.microbatch, dims.seq);
                    (
                        HostTensor::from_i32(&[dims.microbatch, dims.seq], t),
                        HostTensor::from_i32(&[dims.microbatch, dims.seq], g),
                    )
                })
                .collect()
        })
        .collect()
}

#[test]
fn preemption_cycle_resumes_training_exactly() {
    let Some(e) = engine() else { return };
    let dims = e.manifest.dims;
    let k = 2;
    let adam_cfg = AdamConfig { lr: 2e-3, ..Default::default() };

    // Phase 1: two asymmetric DP groups, 6 steps, checkpoint.
    let topo_a = ExecTopology::from_layer_splits(&[vec![2, 2], vec![4]]);
    let mut tr = PipelineTrainer::new(&e, &topo_a, k, adam_cfg, 77).unwrap();
    let mut corpus = MarkovCorpus::new(dims.vocab, 4, 9);
    let mut losses = Vec::new();
    for _ in 0..6 {
        let b = batches(&mut corpus, dims, 2, k);
        losses.push(tr.step(&b).unwrap().loss);
    }
    let mut mgr = CheckpointManager::new(&tmp("cycle")).unwrap();
    // layers 0-1 live on node 0, layers 2-3 + embed/head on node 1
    mgr.save_full(6, &tr.groups[0].params, Some(&tr.groups[0].adam), 1, &|l| {
        if l <= 1 { 0 } else { 1 }
    })
    .unwrap();
    let saved_params = tr.groups[0].params.clone();

    // Preemption: group 1's node dies; volatile memory wiped; new plan is
    // a single 2-stage pipeline with a *different* layer split.
    mgr.store.wipe_memory();
    mgr.bitmap.drop_node_memory(0);
    mgr.bitmap.drop_node_memory(1);

    let topo_b = ExecTopology::from_layer_splits(&[vec![1, 3]]);
    let mut tr2 = PipelineTrainer::new(&e, &topo_b, k, adam_cfg, 123).unwrap();
    let rep = {
        let g0 = &mut tr2.groups[0];
        mgr.load_full(&mut g0.params, Some(&mut g0.adam), 0).unwrap()
    };
    assert_eq!(tr2.groups[0].params.max_abs_diff(&saved_params), 0.0);
    assert!(rep.bytes_disk + rep.bytes_rdma > 0, "local-first load: {rep:?}");
    assert_eq!(rep.bytes_cloud, 0, "nothing should come from the cloud: {rep:?}");

    // Phase 2: training continues and keeps improving.
    let mut post = Vec::new();
    for _ in 0..6 {
        let b = batches(&mut corpus, dims, 1, k);
        post.push(tr2.step(&b).unwrap().loss);
    }
    let pre_last = losses.last().unwrap();
    let post_mean = post.iter().sum::<f64>() / post.len() as f64;
    assert!(
        post_mean < pre_last + 0.5,
        "loss jumped after recovery: {pre_last} -> {post:?}"
    );
}

#[test]
fn node_loss_falls_back_to_cloud_and_matches() {
    let Some(e) = engine() else { return };
    let dims = e.manifest.dims;
    let topo = ExecTopology::single(dims.n_layers);
    let tr = PipelineTrainer::new(&e, &topo, 1, AdamConfig::default(), 5).unwrap();

    let mut mgr = CheckpointManager::new(&tmp("cloud")).unwrap();
    mgr.save_full(1, &tr.groups[0].params, None, 1, &|_| 0).unwrap();
    // node 0 disappears: local disk gone, only cloud remains
    mgr.bitmap.drop_node(0);
    mgr.store.wipe_memory();
    mgr.store.wipe_local().unwrap();

    let mut tr2 = PipelineTrainer::new(&e, &topo, 1, AdamConfig::default(), 6).unwrap();
    let rep = mgr.load_full(&mut tr2.groups[0].params, None, 1).unwrap();
    assert_eq!(tr2.groups[0].params.max_abs_diff(&tr.groups[0].params), 0.0);
    assert!(rep.bytes_cloud > 0);
    // cloud is ~3× slower than NVMe per byte (1.2 vs 3.5 GB/s)
    let per_byte_cloud = rep.sim_s / rep.bytes_cloud as f64;
    assert!(per_byte_cloud > 1.0 / (3.5e9), "{per_byte_cloud}");
}

#[test]
fn tp_resharded_checkpoint_loads_into_trainer() {
    // Save at TP=2 (Fig-6b/c world), load into the TP=1 runtime.
    let Some(e) = engine() else { return };
    let dims = e.manifest.dims;
    let topo = ExecTopology::single(dims.n_layers);
    let tr = PipelineTrainer::new(&e, &topo, 1, AdamConfig::default(), 21).unwrap();

    let mut mgr = CheckpointManager::new(&tmp("tp")).unwrap();
    mgr.save_full(3, &tr.groups[0].params, None, 2, &|_| 0).unwrap();

    let mut tr2 = PipelineTrainer::new(&e, &topo, 1, AdamConfig::default(), 22).unwrap();
    mgr.load_full(&mut tr2.groups[0].params, None, 0).unwrap();
    assert_eq!(tr2.groups[0].params.max_abs_diff(&tr.groups[0].params), 0.0);

    // and the recovered replica still computes the same loss
    let mut corpus = MarkovCorpus::new(dims.vocab, 4, 2);
    let (t, g) = corpus.next_batch(dims.microbatch, dims.seq);
    let batch = vec![(
        HostTensor::from_i32(&[dims.microbatch, dims.seq], t),
        HostTensor::from_i32(&[dims.microbatch, dims.seq], g),
    )];
    let l1 = tr.eval_loss(&batch).unwrap();
    let l2 = tr2.eval_loss(&batch).unwrap();
    assert!((l1 - l2).abs() < 1e-7, "{l1} vs {l2}");
}
