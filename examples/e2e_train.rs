//! End-to-end validation driver: REAL training of a transformer through
//! the full three-layer stack — Pallas-kernel HLO artifacts, executed via
//! PJRT from the Rust coordinator, under an *asymmetric* AutoHet-style
//! plan (group 0: 2-stage pipeline, group 1: single stage), with
//! layer-wise gradient AllReduce and Adam.
//!
//! Defaults to the `tiny` artifact preset for a fast run; pass
//! `--artifacts artifacts/e2e100m --steps 200` after
//! `make artifacts PRESET=e2e100m` to train the ~100M-parameter model.
//! The loss curve lands in `e2e_loss.csv` and is summarized on stdout
//! (recorded in DESIGN.md).
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_train -- --steps 120
//! ```

use std::path::Path;

use autohet::metrics::Recorder;
use autohet::pipeline::{ExecTopology, PipelineTrainer};
use autohet::runtime::{Engine, HostTensor};
use autohet::train::{AdamConfig, MarkovCorpus};
use autohet::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let dir = args.get_str("artifacts", "artifacts/tiny");
    let steps = args.get_usize("steps", 120);
    let k = args.get_usize("k", 2);
    let lr = args.get_f64("lr", 2e-3) as f32;
    let csv = args.get_str("csv", "e2e_loss.csv");

    let engine = Engine::load(Path::new(dir))?;
    let dims = engine.manifest.dims;
    println!(
        "loaded preset `{}`: {:.1}M params, {} layers, platform {}",
        engine.manifest.preset,
        dims.params_count as f64 / 1e6,
        dims.n_layers,
        engine.platform()
    );

    // Asymmetric plan: half/half pipeline group + monolithic group —
    // the Observation-2 shape (stage counts differ across DP groups).
    let h = dims.n_layers / 2;
    let topo = ExecTopology::from_layer_splits(&[vec![h, dims.n_layers - h], vec![dims.n_layers]]);
    println!("topology: group0 = [{h},{}] (2-stage PP), group1 = [{}] (1 stage)", dims.n_layers - h, dims.n_layers);

    let mut trainer = PipelineTrainer::new(
        &engine,
        &topo,
        k,
        AdamConfig { lr, ..Default::default() },
        7,
    )?;
    let mut corpus = MarkovCorpus::new(dims.vocab, 4, 99);
    let mut rec = Recorder::new();

    for step in 0..steps {
        let batches: Vec<Vec<(HostTensor, HostTensor)>> = (0..trainer.groups.len())
            .map(|_| {
                (0..k)
                    .map(|_| {
                        let (t, g) = corpus.next_batch(dims.microbatch, dims.seq);
                        (
                            HostTensor::from_i32(&[dims.microbatch, dims.seq], t),
                            HostTensor::from_i32(&[dims.microbatch, dims.seq], g),
                        )
                    })
                    .collect()
            })
            .collect();
        let stats = trainer.step(&batches)?;
        rec.record(
            step as u64,
            stats.loss,
            stats.grad_norm as f64,
            (stats.microbatches * dims.microbatch * dims.seq) as u64,
        );
        if step % 10 == 0 || step + 1 == steps {
            println!(
                "step {step:>4}/{steps}  loss {:.4}  |g| {:.3}  {:.0} tok/s  replicas synced: {}",
                stats.loss,
                stats.grad_norm,
                rec.tokens_per_s(),
                trainer.replicas_synced(1e-5)
            );
        }
    }

    std::fs::write(csv, rec.to_csv())?;
    let (head, tail) = rec.loss_drop().expect("enough steps");
    println!("\n== e2e summary ==");
    println!("loss {head:.4} -> {tail:.4} | corpus entropy floor ln(4) = {:.4}", (4.0f64).ln());
    println!("throughput {:.0} tokens/s | mean step {:.3}s", rec.tokens_per_s(), rec.mean_step_s());
    println!("loss curve written to {csv}");
    anyhow::ensure!(tail < head, "loss did not decrease");
    Ok(())
}
