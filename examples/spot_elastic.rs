//! Spot-instance elasticity demo on the REAL training path: train under
//! one plan, take layer-wise checkpoints, suffer a preemption (volatile
//! state wiped, topology changes), recover local-first, keep training;
//! then a capacity *grant* arrives and the plan grows back.
//!
//! ```sh
//! make artifacts && cargo run --release --example spot_elastic
//! ```

use std::path::Path;

use autohet::checkpoint::CheckpointManager;
use autohet::pipeline::{ExecTopology, PipelineTrainer};
use autohet::runtime::{Engine, HostTensor};
use autohet::train::{AdamConfig, MarkovCorpus};
use autohet::util::cli::Args;

fn batches(
    corpus: &mut MarkovCorpus,
    dims: autohet::runtime::ModelDims,
    groups: usize,
    k: usize,
) -> Vec<Vec<(HostTensor, HostTensor)>> {
    (0..groups)
        .map(|_| {
            (0..k)
                .map(|_| {
                    let (t, g) = corpus.next_batch(dims.microbatch, dims.seq);
                    (
                        HostTensor::from_i32(&[dims.microbatch, dims.seq], t),
                        HostTensor::from_i32(&[dims.microbatch, dims.seq], g),
                    )
                })
                .collect()
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let dir = args.get_str("artifacts", "artifacts/tiny");
    let engine = Engine::load(Path::new(dir))?;
    let dims = engine.manifest.dims;
    let k = 2;
    let adam = AdamConfig { lr: 2e-3, ..Default::default() };
    let mut corpus = MarkovCorpus::new(dims.vocab, 4, 5);
    let ckpt_root = std::env::temp_dir().join(format!("ah-spot-{}", std::process::id()));
    let mut mgr = CheckpointManager::new(&ckpt_root)?;

    // ---- phase 1: 2 asymmetric DP groups ----
    let h = dims.n_layers / 2;
    let topo1 = ExecTopology::from_layer_splits(&[vec![h, dims.n_layers - h], vec![dims.n_layers]]);
    let mut tr = PipelineTrainer::new(&engine, &topo1, k, adam, 1)?;
    println!("phase 1: dp2 asymmetric [{}+{} | {}]", h, dims.n_layers - h, dims.n_layers);
    for step in 0..10 {
        let b = batches(&mut corpus, dims, 2, k);
        let s = tr.step(&b)?;
        println!("  step {step:>2} loss {:.4}", s.loss);
    }
    // layer-wise checkpoint: early layers on node 0, rest on node 1
    let save = mgr.save_full(10, &tr.groups[0].params, Some(&tr.groups[0].adam), 1, &|l| {
        usize::from(l >= h)
    })?;
    println!(
        "checkpointed {} units: {:.1} MB local ({:.2}s sim) + cloud replica ({:.2}s sim)",
        save.units,
        save.bytes_local as f64 / 1e6,
        save.sim_local_s,
        save.sim_cloud_s
    );

    // ---- preemption: group 1's node is reclaimed ----
    println!("\n!! PREEMPTION: node 1 reclaimed; volatile memory wiped");
    mgr.store.wipe_memory();
    mgr.bitmap.drop_node_memory(0);
    mgr.bitmap.drop_node(1); // node 1's disk is gone too
    let topo2 = ExecTopology::from_layer_splits(&[vec![dims.n_layers]]);
    let mut tr2 = PipelineTrainer::new(&engine, &topo2, k, adam, 2)?;
    let rep = {
        let g0 = &mut tr2.groups[0];
        mgr.load_full(&mut g0.params, Some(&mut g0.adam), 0)?
    };
    println!(
        "recovered: {:.1} MB disk + {:.1} MB cloud (missing pieces) in {:.2}s simulated",
        rep.bytes_disk as f64 / 1e6,
        rep.bytes_cloud as f64 / 1e6,
        rep.sim_s
    );
    assert_eq!(tr2.groups[0].params.max_abs_diff(&tr.groups[0].params), 0.0);
    println!("state bit-identical after recovery ✓");

    println!("\nphase 2: dp1 [{}]", dims.n_layers);
    for step in 10..16 {
        let b = batches(&mut corpus, dims, 1, k);
        let s = tr2.step(&b)?;
        println!("  step {step:>2} loss {:.4}", s.loss);
    }

    // ---- grant: capacity returns, grow to 2 groups again ----
    println!("\n++ GRANT: capacity restored; replanning to dp2");
    let save2 = mgr.save_full(16, &tr2.groups[0].params, Some(&tr2.groups[0].adam), 1, &|_| 0)?;
    let topo3 = ExecTopology::from_layer_splits(&[vec![1, dims.n_layers - 1], vec![dims.n_layers]]);
    let mut tr3 = PipelineTrainer::new(&engine, &topo3, k, adam, 3)?;
    for gi in 0..tr3.groups.len() {
        let g = &mut tr3.groups[gi];
        mgr.load_full(&mut g.params, Some(&mut g.adam), 0)?;
    }
    println!("redistributed {} units to 2 replicas (RDMA path in sim terms)", save2.units);
    for step in 16..22 {
        let b = batches(&mut corpus, dims, 2, k);
        let s = tr3.step(&b)?;
        println!("  step {step:>2} loss {:.4} (replicas synced: {})", s.loss, tr3.replicas_synced(1e-5));
    }
    println!("\nelastic cycle complete: dp2 -> preempt -> dp1 -> grant -> dp2, loss continuous");
    Ok(())
}
