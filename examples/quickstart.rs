//! Quickstart: plan 3D parallelism for a heterogeneous cluster and
//! compare AutoHet against Megatron-LM and Whale in the simulator.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use autohet::baselines::{megatron::plan_megatron, whale::plan_whale};
use autohet::cluster::{ClusterSpec, GpuCatalog, KindId};
use autohet::modelcfg::ModelCfg;
use autohet::planner::{auto_plan, PlanOptions};
use autohet::profile::ProfileDb;
use autohet::sim::simulate_plan;

fn main() -> anyhow::Result<()> {
    // 1. Describe the heterogeneous cluster (the paper's 4×A100 + 4×H800).
    let cluster = ClusterSpec::from_counts(&[(4, KindId::A100), (4, KindId::H800)]);
    println!(
        "cluster: {} GPUs, {:.0} GiB HBM, Σg = {:.1}",
        cluster.total_gpus(),
        cluster.total_mem_gib(),
        cluster.total_power()
    );

    // 2. Pick a model and profile it (binary-decomposition profiling, Eq 5).
    let model = ModelCfg::gpt3_6p7b();
    let profile = ProfileDb::build(&model, &GpuCatalog::builtin(), &[1, 2, 4, 8], 1);
    println!(
        "model: {} ({:.1}B params), profiled {} points (~{:.1} min emulated)",
        model.name,
        model.total_params() / 1e9,
        profile.points(),
        profile.profiling_cost_s() / 60.0
    );

    // 3. Run Algorithm 1.
    let plan = auto_plan(&cluster, &profile, &PlanOptions::default())?;
    println!("\nAutoHet plan:   {}", plan.summary(&cluster.catalog));
    println!("planned in {:.2}s, Eq-1 estimate {:.3}s/iter", plan.planning_s, plan.est_iter_s);

    // 4. Compare in the event simulator.
    let auto = simulate_plan(&profile, &plan);
    println!("\n{:<12} {:>12} {:>10} {:>8}", "system", "tokens/s", "iter (s)", "vs mega");
    let mega = plan_megatron(&cluster, &profile).expect("megatron plan");
    let mega_stats = simulate_plan(&profile, &mega);
    let whale = plan_whale(&cluster, &profile).expect("whale plan");
    let whale_stats = simulate_plan(&profile, &whale);
    for (name, s) in [
        ("Megatron-LM", &mega_stats),
        ("Whale", &whale_stats),
        ("AutoHet", &auto),
    ] {
        println!(
            "{:<12} {:>12.0} {:>10.3} {:>7.2}x",
            name,
            s.tokens_per_s,
            s.iter_s,
            s.tokens_per_s / mega_stats.tokens_per_s
        );
    }
    Ok(())
}
