//! Price-aware device-subset planning on a spot fleet with a straggler
//! kind: the subset planner benches the weak part when that wins, and
//! the cost objective reports what each plan pays per token.
//!
//! ```sh
//! cargo run --release --example subset_pricing
//! ```
//!
//! The fleet is 4×A100 plus one very weak (but cheap) "P4" spot part.
//! Eq-3's exact coverage must place the P4 in some DP group, dragging
//! the whole iteration; benching it is both faster *and* cheaper per
//! token. See `docs/PLANNER.md` for the hand-worked version.

use autohet::cluster::{ClusterSpec, GpuCatalog, GpuSpec, KindId};
use autohet::modelcfg::ModelCfg;
use autohet::planner::{plan_choice, PlanOptions, ScoredPlan};
use autohet::profile::ProfileDb;
use autohet::util::bench::Table;

fn row(table: &mut Table, label: &str, s: &ScoredPlan, cat: &GpuCatalog) {
    // benched is in TP entities; render GPU counts (entities × tp_dim)
    let benched: Vec<String> = cat
        .ids()
        .filter(|&k| s.benched[k] > 0)
        .map(|k| format!("{}x{}", s.benched[k] * s.plan.tp_dim, cat.name(k)))
        .collect();
    table.row(&[
        label.to_string(),
        s.plan.summary(cat),
        if benched.is_empty() { "-".to_string() } else { benched.join(",") },
        format!("{:.3}", s.plan.est_iter_s),
        format!("{:.3}", s.eq1_iter_s),
        format!("{:.2}", s.price_per_hour),
        format!("{:.6}", s.cost_per_iter_usd),
        format!("{:.0}", s.tokens_per_usd),
    ]);
}

fn main() -> anyhow::Result<()> {
    // Catalog: the paper's A100 plus a pathologically weak spot part.
    let mut cat = GpuCatalog::builtin();
    cat.add(GpuSpec {
        name: "P4".into(),
        relative_power: 0.02,
        flops_tf: 2.8,
        mem_gib: 80.0,
        nvlink_gbs: 300.0,
        hbm_gbs: 900.0,
        price_per_hour: 0.2,
        rdma_nics: 1,
    })?;
    let p4 = cat.lookup("P4")?;
    let cluster = ClusterSpec::from_counts_in(&cat, &[(4, KindId::A100), (1, p4)]);
    let model = ModelCfg::bert_large();
    let profile = ProfileDb::build(&model, &cat, &[1, 2, 4, 8], 1);

    let mut table = Table::new(&[
        "planner", "plan", "benched", "sim_s", "eq1_s", "$/h", "$/iter", "tok/$",
    ]);

    // Paper semantics: every device must be placed.
    let all = plan_choice(&cluster, &profile, &PlanOptions::default())?;
    row(&mut table, "all-devices", &all.fastest, &cat);

    // Subset planning: the straggler may be benched.
    let opts = PlanOptions { bench: true, ..Default::default() };
    let choice = plan_choice(&cluster, &profile, &opts)?;
    row(&mut table, "subset (time)", &choice.fastest, &cat);
    row(&mut table, "subset (cost)", &choice.cheapest, &cat);

    table.print("BERT-Large on 4xA100 + 1xP4 straggler (simulated)");

    let speedup = all.fastest.plan.est_iter_s / choice.fastest.plan.est_iter_s;
    let savings = 100.0 * (1.0 - choice.cheapest.cost_per_iter_usd / all.fastest.cost_per_iter_usd);
    println!("\nbenching the straggler: {speedup:.2}x faster, {savings:.1}% cheaper per iteration");
    Ok(())
}
