//! Planner sweep: AutoHet vs baselines across the paper's cluster
//! configurations + the elastic replanning loop driven by a generated
//! spot trace (Figure 1 world).
//!
//! ```sh
//! cargo run --release --example planner_sweep
//! ```

use autohet::baselines::{megatron::plan_megatron, whale::plan_whale};
use autohet::cluster::{ClusterSpec, GpuCatalog, KindId, SpotTrace, TraceConfig};
use autohet::modelcfg::ModelCfg;
use autohet::planner::{auto_plan, PlanOptions};
use autohet::profile::ProfileDb;
use autohet::recovery::ElasticCoordinator;
use autohet::sim::simulate_plan;
use autohet::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let cat = GpuCatalog::builtin();
    let model = ModelCfg::llama_7b();
    let profile = ProfileDb::build(&model, &cat, &[1, 2, 4, 8], 1);

    let mut table = Table::new(&["cluster", "autohet", "megatron", "whale", "plan", "time_s"]);
    for counts in [
        vec![(4usize, KindId::A100), (2, KindId::H800)],
        vec![(5, KindId::A100), (3, KindId::H800)],
        vec![(3, KindId::A100), (5, KindId::H800)],
        vec![(1, KindId::A100), (4, KindId::H20)],
        vec![(8, KindId::A100), (8, KindId::H800)],
    ] {
        let cluster = ClusterSpec::from_counts(&counts);
        let label: Vec<String> =
            counts.iter().map(|(n, k)| format!("{n}x{}", cat.name(*k))).collect();
        let auto = auto_plan(&cluster, &profile, &PlanOptions::default())?;
        let ta = simulate_plan(&profile, &auto).tokens_per_s;
        let tm = plan_megatron(&cluster, &profile)
            .map(|p| simulate_plan(&profile, &p).tokens_per_s)
            .unwrap_or(f64::NAN);
        let tw = plan_whale(&cluster, &profile)
            .map(|p| simulate_plan(&profile, &p).tokens_per_s)
            .unwrap_or(f64::NAN);
        table.row(&[
            label.join("+"),
            format!("{ta:.0}"),
            format!("{tm:.0}"),
            format!("{tw:.0}"),
            auto.summary(&cat),
            format!("{:.2}", auto.planning_s),
        ]);
    }
    table.print("LLaMA-6.7B tokens/s across clusters (simulated)");

    // --- elastic loop over a spot trace ---
    println!("\n== elastic replanning over a 12h spot trace ==");
    let trace = SpotTrace::generate(
        TraceConfig { horizon_s: 12.0 * 3600.0, ..Default::default() },
        7,
    );
    let cluster = ClusterSpec::from_counts(&[(8, KindId::A100), (4, KindId::H800)]);
    let mut coord = ElasticCoordinator::new(model.clone(), profile, cluster)?;
    let mut handled = 0;
    for ev in trace.events().into_iter().take(12) {
        let out = coord.handle_event(&ev)?;
        handled += 1;
        match &out.plan {
            Some(p) => println!(
                "t={:>7.0}s {:+3} {:<5} -> {:>2} GPUs [{}] plan {} (dp {} -> {}, migration {:.0}s)",
                ev.at_s,
                ev.delta,
                cat.name(ev.kind),
                out.cluster.total_gpus(),
                out.decision,
                p.summary(&cat),
                out.dp_change.0,
                out.dp_change.1,
                out.migration_s
            ),
            None => println!(
                "t={:>7.0}s {:+3} {:<5} -> {:>2} GPUs: NO FEASIBLE PLAN (training pauses)",
                ev.at_s,
                ev.delta,
                cat.name(ev.kind),
                out.cluster.total_gpus()
            ),
        }
    }
    println!(
        "handled {handled} availability events: {} migrations taken, {} held by the \
         amortization rule, {} unchanged (see `autohet replay` for the full engine)",
        coord.replans, coord.holds, coord.unchanged
    );
    Ok(())
}
