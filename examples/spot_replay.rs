//! Spot-market replay demo: the same seeded 6-hour price-dynamic trace
//! driven through the elastic coordinator under both replan policies —
//! greedy (the seed coordinator: migrate on every delta) vs amortized
//! (migrate only when the gain repays the downtime).
//!
//! ```sh
//! cargo run --release --example spot_replay [-- --seed N --hours H]
//! ```
//!
//! Runs on the simulator only (no artifacts needed) — this is the CI
//! smoke test for the scenario engine.

use autohet::cluster::{GpuCatalog, SpotTrace, TraceConfig};
use autohet::modelcfg::ModelCfg;
use autohet::planner::Objective;
use autohet::profile::ProfileDb;
use autohet::recovery::{replay, ReplanPolicy, ReplayConfig};
use autohet::util::bench::Table;
use autohet::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let seed = args.get_u64("seed", 7);
    let hours = args.get_f64("hours", 6.0);

    let cat = GpuCatalog::builtin();
    let model = ModelCfg::bert_large();
    let profile = ProfileDb::build(&model, &cat, &[1, 2, 4, 8], 1);
    let tc = TraceConfig {
        horizon_s: hours * 3600.0,
        step_s: 900.0,
        ..TraceConfig::from_catalog(&cat, 6)
    };
    let trace = SpotTrace::generate(tc, seed);
    println!(
        "{} market events over {hours:.0}h (seed {seed}), fleet ≤ {} GPUs\n",
        trace.market_events(0.05).len(),
        trace.cfg.capacity.iter().map(|&(_, c)| c).sum::<usize>()
    );

    let mut t = Table::new(&[
        "policy", "tokens", "usd", "tokens/$", "migration_min", "paused_h", "switches", "holds",
    ]);
    for (name, policy) in [
        ("greedy", ReplanPolicy::Greedy),
        ("amortized", ReplanPolicy::default()),
    ] {
        let cfg = ReplayConfig { objective: Objective::Time, policy, ..Default::default() };
        let r = replay(&profile, &trace, &cfg)?;
        t.row(&[
            name.to_string(),
            format!("{:.2e}", r.tokens),
            format!("{:.2}", r.usd),
            format!("{:.0}", r.tokens_per_usd()),
            format!("{:.1}", r.downtime_s / 60.0),
            format!("{:.2}", r.paused_s / 3600.0),
            r.switches.to_string(),
            r.holds.to_string(),
        ]);
    }
    t.print("Spot-market replay: greedy vs amortized replanning (identical trace)");
    println!("\namortized replanning holds marginal moves; greedy pays migration on each.");
    Ok(())
}
