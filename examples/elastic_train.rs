//! Elastic-training enactment demo: a seeded spot-market trace is
//! replayed for its decision log, then **enacted** on the real PJRT
//! training path — real optimizer steps per market segment, layer-wise
//! checkpoint save/load through the tiered store on every replan, and a
//! final loss-level comparison against the uninterrupted baseline run
//! with identical seeds.
//!
//! ```sh
//! cd python && python -m compile.aot --preset tiny --out-dir ../rust/artifacts
//! cargo run --release --example elastic_train -- --hours 2
//! ```
//!
//! Prints a SKIP notice and exits cleanly when the AOT artifacts are
//! absent, so it can ride in CI next to the artifact-free demos.

use std::path::Path;

use autohet::cluster::{GpuCatalog, SpotTrace, TraceConfig};
use autohet::modelcfg::ModelCfg;
use autohet::profile::ProfileDb;
use autohet::recovery::{baseline_train, enact, replay, EnactConfig};
use autohet::runtime::Engine;
use autohet::util::bench::Table;
use autohet::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let dir = args.get_str("artifacts", "artifacts/tiny");
    if !Path::new(dir).join("manifest.json").exists() {
        println!("SKIP: no AOT artifacts at `{dir}`; generate them with");
        println!("  cd python && python -m compile.aot --preset tiny --out-dir ../rust/artifacts");
        return Ok(());
    }
    let engine = Engine::load(Path::new(dir))?;
    let seed = args.get_u64("seed", 7);
    let hours = args.get_f64("hours", 2.0);

    let cat = GpuCatalog::builtin();
    let model = ModelCfg::bert_large();
    let profile = ProfileDb::build(&model, &cat, &[1, 2, 4, 8], 1);
    let tc = TraceConfig {
        horizon_s: hours * 3600.0,
        step_s: 900.0,
        ..TraceConfig::from_catalog(&cat, 6)
    };
    let trace = SpotTrace::generate(tc, seed);

    let ckpt_dir = std::env::temp_dir().join(format!(
        "autohet-elastic-train-{}-{seed}",
        std::process::id()
    ));
    let cfg = EnactConfig {
        steps_per_event: args.get_usize("steps-per-event", 4),
        seed,
        ckpt_dir,
        ..Default::default()
    };

    let log = replay(&profile, &trace, &cfg.replay)?;
    println!(
        "decision log: {} events over {hours:.1}h (seed {seed}) — {} switches, {} holds\n",
        log.events, log.switches, log.holds
    );

    let report = enact(&engine, &profile, &trace, &cfg)?;
    let mut t = Table::new(&[
        "t_h", "decision", "gpus", "steps", "loss", "save_B", "load_B", "cloud_frac", "fig10_s",
    ]);
    for r in &report.rows {
        let load = r.load.clone().unwrap_or_default();
        t.row(&[
            format!("{:.2}", r.at_s / 3600.0),
            format!("{}{}", r.decision, if r.forced { "*" } else { "" }),
            r.gpus.to_string(),
            r.steps_run.to_string(),
            format!("{:.4}", r.loss_before),
            r.save.bytes_local.to_string(),
            load.total_bytes().to_string(),
            format!("{:.2}", r.cloud_frac),
            format!("{:.0}", r.timing_model_s),
        ]);
    }
    t.print("Enacted market events (decisions taken on the REAL training path)");

    let dims = engine.manifest.dims;
    let (base_losses, base_eval) =
        baseline_train(&engine, &[vec![dims.n_layers]], report.steps, &cfg)?;
    println!("\ndecision log matches replay: {}", report.matches_decision_log(&log));
    println!(
        "enacted:  {} steps | final train {:.4} | eval {:.4} | replicas synced: {}",
        report.steps, report.final_train_loss, report.final_eval_loss, report.replicas_synced
    );
    println!(
        "baseline: {} steps | final train {:.4} | eval {:.4} | Δeval {:+.4}",
        base_losses.len(),
        base_losses.last().copied().unwrap_or(f64::NAN),
        base_eval,
        report.final_eval_loss - base_eval
    );
    anyhow::ensure!(
        report.matches_decision_log(&log),
        "enactment diverged from the replay decision log"
    );
    Ok(())
}
